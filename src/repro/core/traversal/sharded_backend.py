"""Sharded traversal backend over the range-sharded pool (DESIGN.md §9).

Third backend of the unified edgeMap engine: the same algorithm text
that runs on ``NumpyEngine`` (FlatSnapshot) and ``JaxEngine``
(single-chip FlatGraph) runs here over ``sharded_pool.ShardedGraph`` —
the pool whose updates already scale with the mesh.  Every query step
is an EXPLICIT ``shard_map``: edge data never moves, and the only wire
traffic per edgeMap round is the frontier-sized vertex-state collective
(O(n) words, not O(pool) edges — the same O(batch)-not-O(pool)
argument the sharded update step makes, applied to queries).

How arbitrary F/C callbacks stay correct across shards
------------------------------------------------------
The backend contract (base.py) requires every state write to go
through the masked ``ops.scatter_*`` helpers.  ``ShardedOps`` exploits
exactly that: inside the shard_map'd step each shard runs F over its
OWN edge lanes, and each scatter helper merges its contribution with
one collective —

  scatter_add  ->  target + psum(local delta)
  scatter_max  ->  max(target, pmax(local candidates))
  scatter_min  ->  min(target, pmin(local candidates))
  scatter_or   ->  target | (pmax(local hits) > 0)

add/max/min/or are commutative and associative, so the merged result
is identical to one global scatter over the union of all shards' edges
(each edge lives in exactly one shard) — and after F returns, the
state and out-mask are REPLICATED on every device, which is what lets
the frontier loop iterate without ever gathering edge data.  The
Beamer direction rule runs on psum'd frontier degrees (each shard
knows only its local degree contribution), so push/pull decisions are
identical to the single-chip engines and the parity suite holds
exactly.

``edge_map_reduce(_batch)`` (PageRank's inner loop) is a shard-local
segmented row-sum over each shard's dst-major lanes followed by ONE
tiled ``psum_scatter`` over the padded vertex axis — O(B · n) words on
the wire, each device left holding exactly the output chunk the
out_spec reassembles.  The in-trace ``bfs_batch_sharded`` /
``sssp_batch_sharded`` drivers port the single-chip ``lax.while_loop``
drivers with a pmax/pmin/psum merge per round, preserving the
ONE-dispatch / O(1)-host-syncs contract.

``collective_operand_bytes`` is the collective-bytes spy tests use to
pin the O(frontier + batch)-not-O(pool) wire contract on the jaxpr.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from .. import compressed as cz
from ..sharded_pool import (
    CompressedShardedGraph,
    CompressedShardedPool,
    ShardAux,
    ShardedGraph,
    _decompress_pool_impl,
    _shard_map,
    graph_num_edges,
    pool_mesh,
    shard_aux,
)
from .base import DENSE_THRESHOLD_DENOM, TRACES, TraversalEngine
from .jax_backend import (
    JaxEngine,
    JaxOps,
    JaxVertexSubset,
    _round_up,
    _segmin_rows,
    _segsum_rows,
    _sparse_expand,
)

AXIS = "shard"

_SPEC2 = P(AXIS, None)


def _neutral_min(dtype):
    """Identity of max (the lowest representable value)."""
    d = np.dtype(dtype)
    if d == np.bool_:
        return False
    if np.issubdtype(d, np.floating):
        return -np.inf
    return np.iinfo(d).min


def _neutral_max(dtype):
    d = np.dtype(dtype)
    if d == np.bool_:
        return True
    if np.issubdtype(d, np.floating):
        return np.inf
    return np.iinfo(d).max


class ShardedOps(JaxOps):
    """JaxOps whose scatter helpers merge across the shard axis.

    The collective forms are only valid inside the backend's shard_map'd
    steps (they need the ``shard`` axis bound); F/C callbacks are the
    only contract call sites that scatter, and the engine runs them
    exactly there.  Instances hash/compare by dtype + axis so the jit
    step cache stays shared across engines."""

    def __init__(self, float_dtype=jnp.float32, axis_name: str = AXIS):
        super().__init__(float_dtype)
        self.axis_name = axis_name

    def __eq__(self, other):
        return (
            type(other) is type(self)
            and np.dtype(other.float_dtype) == np.dtype(self.float_dtype)
            and other.axis_name == self.axis_name
        )

    def __hash__(self):
        return hash((type(self), np.dtype(self.float_dtype).name, self.axis_name))

    def scatter_max(self, target, idx, vals, mask):
        neutral = jnp.asarray(_neutral_min(target.dtype), target.dtype)
        local = jnp.full_like(target, neutral).at[
            self._safe_idx(target, idx, mask)
        ].max(vals, mode="drop")
        return jnp.maximum(target, jax.lax.pmax(local, self.axis_name))

    def scatter_min(self, target, idx, vals, mask):
        neutral = jnp.asarray(_neutral_max(target.dtype), target.dtype)
        local = jnp.full_like(target, neutral).at[
            self._safe_idx(target, idx, mask)
        ].min(vals, mode="drop")
        return jnp.minimum(target, jax.lax.pmin(local, self.axis_name))

    def scatter_add(self, target, idx, vals, mask):
        vals = jnp.where(mask, vals, jnp.zeros((), target.dtype))
        delta = jnp.zeros_like(target).at[
            self._safe_idx(target, idx, mask)
        ].add(vals, mode="drop")
        return target + jax.lax.psum(delta, self.axis_name)

    def scatter_or(self, target, idx, mask):
        local = jnp.zeros(target.shape, jnp.int32).at[
            self._safe_idx(target, idx, mask)
        ].max(1, mode="drop")
        return target | (jax.lax.pmax(local, self.axis_name) > 0)


SHARDED_OPS = ShardedOps()


def _expand_block(offsets, keys, vals, U, n, ids_budget, edge_budget):
    """Sparse push expansion of one frontier over a BLOCK of shard rows:
    vmap the fixed-shape single-row expansion and flatten the edge lanes
    (each edge lives in exactly one row, so concatenation is the union)."""

    def one_row(off_row, key_row):
        return _sparse_expand(off_row, key_row, U, n, ids_budget, edge_budget)

    us, vs, ev, eidx = jax.vmap(one_row)(offsets, keys)
    ws = None if vals is None else jnp.take_along_axis(vals, eidx, axis=1).reshape(-1)
    return us.reshape(-1), vs.reshape(-1), ev.reshape(-1), ws


# ---------------------------------------------------------------------------
# the shard_map'd edgeMap step (module-level jit: cache shared across engines)
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit,
    static_argnames=(
        "F", "C", "mode", "n", "ids_budget", "edge_budget", "ops", "mesh", "weighted",
    ),
)
def _sharded_edge_map_step(
    offsets,  # int32[S, n+1] per-shard CSR
    keys,  # int64[S, cap]
    src_c,  # int32[S, cap]
    dst_c,  # int32[S, cap]
    evalid,  # bool[S, cap]
    degrees,  # int32[S, n] per-shard degree contributions
    m,  # int32 scalar: global edge count
    vals,  # float32[S, cap] per-edge values, or None (unweighted)
    U,  # bool[n] frontier (replicated)
    state,  # pytree (replicated)
    *,
    F: Callable,
    C: Callable,
    mode: str,
    n: int,
    ids_budget: int,
    edge_budget: int,
    ops: ShardedOps,
    mesh: Mesh,
    weighted: bool,
):
    def body(offsets, keys, src_c, dst_c, evalid, degrees, vals, m, U, state):
        src_f = src_c.reshape(-1)
        dst_f = dst_c.reshape(-1)
        ev_f = evalid.reshape(-1)
        w_f = None if vals is None else vals.reshape(-1)
        cmask = C(ops, state, jnp.arange(n, dtype=jnp.int32))

        def dense_branch(state):
            valid = ev_f & U[src_f] & cmask[dst_f]
            return F(ops, state, src_f, dst_f, w_f, valid)

        def sparse_branch(state):
            us, vs, ev, ws = _expand_block(
                offsets, keys, vals, U, n, ids_budget, edge_budget
            )
            return F(ops, state, us, vs, ws, ev & cmask[vs])

        if mode == "dense":
            return dense_branch(state)
        if mode == "sparse":
            return sparse_branch(state)
        # auto: Beamer rule on psum'd frontier degrees — one scalar psum
        # makes the direction decision globally consistent
        size = U.sum()
        deg_u = jax.lax.psum(jnp.where(U, degrees.sum(axis=0), 0).sum(), AXIS)
        use_dense = (size + deg_u) > jnp.maximum(1, m // DENSE_THRESHOLD_DENOM)
        return jax.lax.cond(use_dense, dense_branch, sparse_branch, state)

    if weighted:
        local = body
        args = (offsets, keys, src_c, dst_c, evalid, degrees, vals, m, U, state)
        specs = (_SPEC2,) * 7 + (P(), P(), P())
    else:
        def local(offsets, keys, src_c, dst_c, evalid, degrees, m, U, state):
            return body(offsets, keys, src_c, dst_c, evalid, degrees, None, m, U, state)

        args = (offsets, keys, src_c, dst_c, evalid, degrees, m, U, state)
        specs = (_SPEC2,) * 6 + (P(), P(), P())
    return _shard_map(
        local, mesh=mesh, in_specs=specs, out_specs=(P(), P()), check_rep=False
    )(*args)


# ---------------------------------------------------------------------------
# dense semiring reduce: shard-local segment-sum + ONE psum_scatter
# ---------------------------------------------------------------------------


def _reduce_partial(sbd, vbd, bounds, wbd, values_b, n_pad, dtype):
    """Per-device partial of the (+, x) reduce over a block of rows,
    psum_scatter'd so each device keeps its own vertex chunk."""

    def one(srow, vrow, brow, wrow):
        msg = jnp.where(vrow[None, :], values_b[:, srow], 0.0).astype(dtype)
        if wrow is not None:
            msg = msg * wrow[None, :].astype(dtype)
        return _segsum_rows(msg, brow)

    if wbd is None:
        parts = jax.vmap(lambda s, v, b: one(s, v, b, None))(sbd, vbd, bounds)
    else:
        parts = jax.vmap(one)(sbd, vbd, bounds, wbd)
    partial = parts.sum(axis=0)  # (B, n)
    padded = jnp.pad(partial, ((0, 0), (0, n_pad - partial.shape[1])))
    return jax.lax.psum_scatter(padded, AXIS, scatter_dimension=1, tiled=True)


@functools.partial(jax.jit, static_argnames=("n", "mesh", "weighted", "dtype"))
def _sharded_reduce_batch(
    src_by_dst,  # int32[S, cap]
    valid_by_dst,  # bool[S, cap]
    dst_offsets,  # int32[S, n+1]
    w_by_dst,  # float32[S, cap] or None
    values_b,  # (B, n) replicated value rows
    *,
    n: int,
    mesh: Mesh,
    weighted: bool,
    dtype,
):
    """out[b, v] = sum_{u->v} w(u, v) * values[b, u] over all shards."""
    n_pad = _round_up(max(n, 1), mesh.shape[AXIS])
    if weighted:
        out = _shard_map(
            lambda s, v, b, w, x: _reduce_partial(s, v, b, w, x, n_pad, dtype),
            mesh=mesh,
            in_specs=(_SPEC2, _SPEC2, _SPEC2, _SPEC2, P()),
            out_specs=P(None, AXIS),
            check_rep=False,
        )(src_by_dst, valid_by_dst, dst_offsets, w_by_dst, values_b)
    else:
        out = _shard_map(
            lambda s, v, b, x: _reduce_partial(s, v, b, None, x, n_pad, dtype),
            mesh=mesh,
            in_specs=(_SPEC2, _SPEC2, _SPEC2, P()),
            out_specs=P(None, AXIS),
            check_rep=False,
        )(src_by_dst, valid_by_dst, dst_offsets, values_b)
    return out[:, :n]


# ---------------------------------------------------------------------------
# in-trace batched drivers: whole multi-source traversals, ONE dispatch
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit, static_argnames=("n", "ids_budget", "edge_budget", "mesh")
)
def bfs_batch_sharded(
    offsets,  # int32[S, n+1]
    keys,  # int64[S, cap]
    src_c,  # int32[S, cap]
    dst_c,  # int32[S, cap]
    evalid,  # bool[S, cap]
    degrees,  # int32[S, n]
    src_by_dst,  # int32[S, cap]
    valid_by_dst,  # bool[S, cap]
    dst_offsets,  # int32[S, n+1]
    m,  # int32 scalar: global edge count
    sources,  # int32[B]
    *,
    n: int,
    ids_budget: int,
    edge_budget: int,
    mesh: Mesh,
) -> Tuple[jax.Array, jax.Array]:
    """Multi-source direction-optimized BFS over the sharded pool, fully
    in-trace: the single-chip ``jax_backend.bfs_batch`` driver with a
    pmax/psum merge per round.  Returns ``(parents, depths)`` int32[B, n]
    — bit-identical to the single-chip driver (push is a per-shard
    budget-bounded expand OR-merged across shards; pull is the per-shard
    segmented row-cumsum psum-merged; parents are one final masked
    scatter-max pass pmax-merged, the same max-contention rule)."""
    TRACES.bump()  # trace-time only: a jit cache hit never runs this body

    def local(offsets, keys, src_c, dst_c, evalid, degrees, sbd, vbd, doff, m, sources):
        B = sources.shape[0]
        lane = jnp.arange(B)
        src = sources.astype(jnp.int32)
        depths = jnp.full((B, n), -1, jnp.int32).at[lane, src].set(0)
        frontier = jnp.zeros((B, n), bool).at[lane, src].set(True)
        thresh = jnp.maximum(1, m // DENSE_THRESHOLD_DENOM)
        deg_loc = degrees.sum(axis=0)  # (n,) this device's contribution

        def push(f_b):
            def one(U):
                def one_row(off_row, key_row):
                    us, vs, ev, _ = _sparse_expand(
                        off_row, key_row, U, n, ids_budget, edge_budget
                    )
                    return (
                        jnp.zeros(n, bool)
                        .at[jnp.where(ev, vs, n)]
                        .max(True, mode="drop")
                    )

                return jax.vmap(one_row)(offsets, keys).any(axis=0)

            loc = jax.vmap(one)(f_b)
            return jax.lax.pmax(loc.astype(jnp.int32), AXIS) > 0

        def pull(f_b):
            def one_row(srow, vrow, brow):
                msg = (f_b[:, srow] & vrow[None, :]).astype(jnp.int32)
                return _segsum_rows(msg, brow)

            loc = jax.vmap(one_row)(sbd, vbd, doff).sum(axis=0)
            return jax.lax.psum(loc, AXIS) > 0

        def cond(carry):
            return carry[0].any()

        def body(carry):
            f, dep, d = carry
            size_b = f.sum(axis=1)
            deg_b = jax.lax.psum(
                jnp.where(f, deg_loc[None, :], 0).sum(axis=1), AXIS
            )
            reached = jax.lax.cond(((size_b + deg_b) > thresh).any(), pull, push, f)
            newly = reached & (dep < 0)
            return newly, jnp.where(newly, d + 1, dep), d + 1

        _, depths, _ = jax.lax.while_loop(
            cond, body, (frontier, depths, jnp.int32(0))
        )

        src_f = src_c.reshape(-1)
        dst_f = dst_c.reshape(-1)
        ev_f = evalid.reshape(-1)
        du = depths[:, src_f]
        dv = depths[:, dst_f]
        ok = ev_f[None, :] & (du >= 0) & (dv == du + 1)
        safe = jnp.where(ok, dst_f[None, :], n)
        cand = jnp.full((B, n), -1, jnp.int32).at[lane[:, None], safe].max(
            jnp.broadcast_to(src_f[None, :], (B, src_f.shape[0])), mode="drop"
        )
        cand = jax.lax.pmax(cand, AXIS)
        vid = jnp.arange(n, dtype=jnp.int32)[None, :]
        parents = jnp.where(depths == 0, vid, jnp.where(depths > 0, cand, -1))
        return parents, depths

    return _shard_map(
        local,
        mesh=mesh,
        in_specs=(_SPEC2,) * 9 + (P(), P()),
        out_specs=(P(), P()),
        check_rep=False,
    )(
        offsets, keys, src_c, dst_c, evalid, degrees,
        src_by_dst, valid_by_dst, dst_offsets, m, sources,
    )


@functools.partial(jax.jit, static_argnames=("n", "mesh", "float_dtype"))
def bc_batch_sharded(
    offsets,  # int32[S, n+1] CSR into each shard's own rows
    src_c,  # int32[S, cap]
    dst_c,  # int32[S, cap]
    evalid,  # bool[S, cap]
    src_by_dst,  # int32[S, cap]
    valid_by_dst,  # bool[S, cap]
    dst_offsets,  # int32[S, n+1]
    sources,  # int32[B]
    *,
    n: int,
    mesh: Mesh,
    float_dtype=jnp.float32,
) -> jax.Array:
    """Multi-source Brandes dependency scores over the sharded pool,
    fully in-trace — the sharded analogue of ``jax_backend.bc_batch``.

    All per-lane state (sigma, depth, dep_acc) is replicated; each round
    every device computes its shards' partial of the (+, x) segmented
    row-sum and ONE psum merges it, in both the forward
    (shortest-path-count) pass over the dst-major pool and the backward
    (dependency) pass over the src-major CSR.  The round structure — one
    collective per BFS level instead of one per edge_map sub-step — is
    what the generic edge_map fallback cannot express."""
    TRACES.bump()  # trace-time only: a jit cache hit never runs this body

    def local(offsets, src_c, dst_c, evalid, sbd, vbd, doff, sources):
        B = sources.shape[0]
        lane = jnp.arange(B)
        src = sources.astype(jnp.int32)
        sigma = jnp.zeros((B, n), float_dtype).at[lane, src].set(1.0)
        depth = jnp.full((B, n), -1, jnp.int32).at[lane, src].set(0)
        frontier = jnp.zeros((B, n), bool).at[lane, src].set(True)

        def fcond(carry):
            return carry[0].any()

        def fbody(carry):
            f, sig, dep, d = carry

            def one_row(srow, vrow, brow):
                w = jnp.where(
                    f[:, srow] & vrow[None, :],
                    sig[:, srow],
                    jnp.zeros((), float_dtype),
                )
                return _segsum_rows(w, brow)

            contrib = jax.lax.psum(
                jax.vmap(one_row)(sbd, vbd, doff).sum(axis=0), AXIS
            )
            newly = (contrib > 0) & (dep < 0)
            sig = sig + jnp.where(newly, contrib, 0)
            return newly, sig, jnp.where(newly, d + 1, dep), d + 1

        _, sigma, depth, d_final = jax.lax.while_loop(
            fcond, fbody, (frontier, sigma, depth, jnp.int32(0))
        )

        def bcond(carry):
            return carry[1] >= 0

        def bbody(carry):
            dep_acc, dd = carry

            def one_row(off_row, srow, drow, ev):
                du = depth[:, srow]
                dv = depth[:, drow]
                ok = ev[None, :] & (du == dd) & (dv == dd + 1)
                ratio = sigma[:, srow] / jnp.maximum(sigma[:, drow], 1e-30)
                contrib = jnp.where(ok, ratio * (1.0 + dep_acc[:, drow]), 0)
                return _segsum_rows(contrib, off_row)

            loc = jax.vmap(one_row)(offsets, src_c, dst_c, evalid).sum(axis=0)
            return dep_acc + jax.lax.psum(loc, AXIS), dd - 1

        dep, _ = jax.lax.while_loop(
            bcond, bbody, (jnp.zeros((B, n), float_dtype), d_final - 2)
        )
        return dep.at[lane, src].set(0.0)

    return _shard_map(
        local,
        mesh=mesh,
        in_specs=(_SPEC2,) * 7 + (P(),),
        out_specs=P(),
        check_rep=False,
    )(offsets, src_c, dst_c, evalid, src_by_dst, valid_by_dst, dst_offsets, sources)


def _sharded_bellman_ford(
    offsets, keys, degrees, sbd, vbd, doff, vals, wbd, m,
    dist, frontier,
    *, n, ids_budget, edge_budget, float_dtype, unit=False,
):
    """The per-device (min, +) relaxation loop shared by
    ``sssp_batch_sharded`` (point sources) and
    ``sssp_batch_sharded_from`` (warm start): runs INSIDE the callers'
    shard_map from whatever replicated (dist, frontier) it is seeded
    with, pmin-merging each round across shards.  ``unit=True`` forces
    unit weights — the hop metric, how incremental BFS rides this
    driver on a weighted pool."""
    inf = jnp.asarray(jnp.inf, float_dtype)
    w_pool = (
        jnp.ones(keys.shape, float_dtype)
        if (unit or vals is None)
        else vals.astype(float_dtype)
    )
    w_dst = (
        jnp.ones(keys.shape, float_dtype)
        if (unit or wbd is None)
        else wbd.astype(float_dtype)
    )
    thresh = jnp.maximum(1, m // DENSE_THRESHOLD_DENOM)
    deg_loc = degrees.sum(axis=0)

    def push(args):
        f_b, d_b = args

        def one(U, d):
            def one_row(off_row, key_row, w_row):
                us, vs, ev, eidx = _sparse_expand(
                    off_row, key_row, U, n, ids_budget, edge_budget
                )
                cand = d[us] + w_row[eidx]
                return (
                    jnp.full(n, inf, float_dtype)
                    .at[jnp.where(ev, vs, n)]
                    .min(cand, mode="drop")
                )

            return jax.vmap(one_row)(offsets, keys, w_pool).min(axis=0)

        loc = jax.vmap(one)(f_b, d_b)
        return jax.lax.pmin(loc, AXIS)

    def pull(args):
        f_b, d_b = args

        def one_row(srow, vrow, brow, wrow):
            msg = jnp.where(
                f_b[:, srow] & vrow[None, :],
                d_b[:, srow] + wrow[None, :],
                inf,
            )
            return _segmin_rows(msg, brow)

        loc = jax.vmap(one_row)(sbd, vbd, doff, w_dst).min(axis=0)
        return jax.lax.pmin(loc, AXIS)

    def cond(carry):
        return carry[0].any()

    def step(carry):
        f, d = carry
        size_b = f.sum(axis=1)
        deg_b = jax.lax.psum(
            jnp.where(f, deg_loc[None, :], 0).sum(axis=1), AXIS
        )
        cand = jax.lax.cond(
            ((size_b + deg_b) > thresh).any(), pull, push, (f, d)
        )
        newly = cand < d
        return newly, jnp.where(newly, cand, d)

    _, dist = jax.lax.while_loop(cond, step, (frontier, dist))
    return dist


@functools.partial(
    jax.jit,
    static_argnames=("n", "ids_budget", "edge_budget", "mesh", "weighted", "float_dtype"),
)
def sssp_batch_sharded(
    offsets,
    keys,
    src_c,
    dst_c,
    evalid,
    degrees,
    src_by_dst,
    valid_by_dst,
    dst_offsets,
    vals,  # float32[S, cap] pool-order values, or None
    w_by_dst,  # float32[S, cap] dst-major values, or None
    m,
    sources,
    *,
    n: int,
    ids_budget: int,
    edge_budget: int,
    mesh: Mesh,
    weighted: bool,
    float_dtype=jnp.float32,
) -> jax.Array:
    """Multi-source Bellman–Ford over the sharded pool, fully in-trace:
    the (min, +) driver of ``jax_backend.sssp_batch`` with a pmin merge
    per round.  Distances are EXACT matches of the single-chip driver:
    every candidate path sum d[u] + w is computed identically and min is
    order-insensitive."""
    TRACES.bump()  # trace-time only: a jit cache hit never runs this body

    def body(offsets, keys, src_c, dst_c, evalid, degrees, sbd, vbd, doff,
             vals, wbd, m, sources):
        B = sources.shape[0]
        lane = jnp.arange(B)
        src = sources.astype(jnp.int32)
        inf = jnp.asarray(jnp.inf, float_dtype)
        dist = jnp.full((B, n), inf, float_dtype).at[lane, src].set(0.0)
        frontier = jnp.zeros((B, n), bool).at[lane, src].set(True)
        return _sharded_bellman_ford(
            offsets, keys, degrees, sbd, vbd, doff, vals, wbd, m,
            dist, frontier,
            n=n, ids_budget=ids_budget, edge_budget=edge_budget,
            float_dtype=float_dtype,
        )

    if weighted:
        local = body
        args = (offsets, keys, src_c, dst_c, evalid, degrees, src_by_dst,
                valid_by_dst, dst_offsets, vals, w_by_dst, m, sources)
        specs = (_SPEC2,) * 11 + (P(), P())
    else:
        def local(offsets, keys, src_c, dst_c, evalid, degrees, sbd, vbd, doff,
                  m, sources):
            return body(offsets, keys, src_c, dst_c, evalid, degrees, sbd, vbd,
                        doff, None, None, m, sources)

        args = (offsets, keys, src_c, dst_c, evalid, degrees, src_by_dst,
                valid_by_dst, dst_offsets, m, sources)
        specs = (_SPEC2,) * 9 + (P(), P())
    return _shard_map(
        local, mesh=mesh, in_specs=specs, out_specs=P(), check_rep=False
    )(*args)


@functools.partial(
    jax.jit,
    static_argnames=(
        "n", "ids_budget", "edge_budget", "mesh", "weighted", "unit", "float_dtype"
    ),
)
def sssp_batch_sharded_from(
    offsets,
    keys,
    src_c,
    dst_c,
    evalid,
    degrees,
    src_by_dst,
    valid_by_dst,
    dst_offsets,
    vals,  # float32[S, cap] pool-order values, or None
    w_by_dst,  # float32[S, cap] dst-major values, or None
    m,
    dist0,  # float[B, n] replicated (+inf = unknown)
    frontier0,  # bool[B, n] replicated initial relax frontier
    *,
    n: int,
    ids_budget: int,
    edge_budget: int,
    mesh: Mesh,
    weighted: bool,
    unit: bool = False,
    float_dtype=jnp.float32,
) -> jax.Array:
    """``sssp_batch_sharded`` seeded from arbitrary replicated state
    instead of point sources — the sharded warm-start entry point of
    the incremental BFS/SSSP path.  Distance/frontier state is
    vertex-shaped and replicated (``P()``), exactly like the in-loop
    carry, so per-round collective traffic stays O(frontier + batch)."""
    TRACES.bump()  # trace-time only: a jit cache hit never runs this body

    def body(offsets, keys, src_c, dst_c, evalid, degrees, sbd, vbd, doff,
             vals, wbd, m, dist0, frontier0):
        return _sharded_bellman_ford(
            offsets, keys, degrees, sbd, vbd, doff, vals, wbd, m,
            dist0.astype(float_dtype), frontier0,
            n=n, ids_budget=ids_budget, edge_budget=edge_budget,
            float_dtype=float_dtype, unit=unit,
        )

    if weighted and not unit:
        local = body
        args = (offsets, keys, src_c, dst_c, evalid, degrees, src_by_dst,
                valid_by_dst, dst_offsets, vals, w_by_dst, m, dist0, frontier0)
        specs = (_SPEC2,) * 11 + (P(), P(), P())
    else:
        def local(offsets, keys, src_c, dst_c, evalid, degrees, sbd, vbd, doff,
                  m, dist0, frontier0):
            return body(offsets, keys, src_c, dst_c, evalid, degrees, sbd, vbd,
                        doff, None, None, m, dist0, frontier0)

        args = (offsets, keys, src_c, dst_c, evalid, degrees, src_by_dst,
                valid_by_dst, dst_offsets, m, dist0, frontier0)
        specs = (_SPEC2,) * 9 + (P(), P(), P())
    return _shard_map(
        local, mesh=mesh, in_specs=specs, out_specs=P(), check_rep=False
    )(*args)


# ---------------------------------------------------------------------------
# weighted degrees (one fixed-shape jit over the sharded aux)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("dtype",))
def _sharded_weighted_degrees(offsets, evalid, vals, dtype):
    def one_row(off_row, ev_row, v_row):
        msg = jnp.where(ev_row, v_row.astype(dtype), 0.0)
        return _segsum_rows(msg[None, :], off_row)[0]

    return jax.vmap(one_row)(offsets, evalid, vals).sum(axis=0)


# ---------------------------------------------------------------------------
# the collective-bytes spy (tests pin the wire contract on the jaxpr)
# ---------------------------------------------------------------------------

COLLECTIVE_PRIMS = frozenset(
    {
        "psum", "pmax", "pmin", "all_gather", "all_to_all",
        "reduce_scatter", "psum_scatter", "ppermute", "pgather",
    }
)


def _walk_jaxpr(jaxpr, out):
    for eqn in jaxpr.eqns:
        if eqn.primitive.name in COLLECTIVE_PRIMS:
            nbytes = sum(
                int(np.prod(v.aval.shape)) * v.aval.dtype.itemsize
                for v in eqn.invars
                if hasattr(v, "aval") and hasattr(v.aval, "shape")
            )
            out.append((eqn.primitive.name, nbytes))
        for v in eqn.params.values():
            for item in v if isinstance(v, (list, tuple)) else (v,):
                inner = getattr(item, "jaxpr", item)
                if hasattr(inner, "eqns"):
                    _walk_jaxpr(inner, out)
    return out


def collective_operand_bytes(fn, *args, **kwargs):
    """Trace ``fn(*args)`` and return ``[(collective_name, operand_bytes),
    ...]`` over every collective in the jaxpr (recursing through cond /
    while / shard_map sub-jaxprs).  Operand byte-sizes are per-device
    logical shapes — the quantity that goes on the wire per round.  The
    O(frontier + batch)-not-O(pool) acceptance tests assert every entry
    is vertex-state-sized, never pool-sized."""
    closed = jax.make_jaxpr(fn, **kwargs)(*args)
    return _walk_jaxpr(closed.jaxpr, [])


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------


class ShardedEngine(TraversalEngine):
    """Engine over an (immutable) ``ShardedGraph``.

    The full backend contract of ``base.py`` — BFS / CC / PageRank /
    SSSP / BC in ``algorithms.py`` run unchanged — plus the in-trace
    ``bfs_batch`` / ``sssp_batch`` drivers ``bfs_multi`` / ``sssp_multi``
    dispatch to.  ``aux`` may be passed in pre-refreshed by a
    version-pinned caller (AspenStream's engine cache)."""

    def __init__(
        self,
        sg: ShardedGraph,
        aux: Optional[ShardAux] = None,
        mesh: Optional[Mesh] = None,
        float_dtype=None,
    ):
        self.sg = sg
        self._n = sg.n
        self.mesh = pool_mesh(sg.n_shards) if mesh is None else mesh
        if sg.n_shards % self.mesh.shape[AXIS] != 0:
            raise ValueError(
                f"n_shards={sg.n_shards} must be a multiple of the mesh "
                f"size {self.mesh.shape[AXIS]}"
            )
        self._m = graph_num_edges(sg)  # one device read per engine build
        self.ops = ShardedOps(jnp.float32 if float_dtype is None else float_dtype)
        self.aux = shard_aux(sg.pool, sg.n) if aux is None else aux
        self._wdeg = None  # lazy weighted out-degree cache

        # static sparse budgets: a frontier routed sparse obeys
        # |U| + deg(U) <= m/20 <= pool_cap/20 globally; the per-row edge
        # budget additionally caps at the row capacity.
        S, cap = sg.pool.data.shape
        total_cap = S * cap
        self._auto_ids_budget = min(
            self._n, _round_up(total_cap // DENSE_THRESHOLD_DENOM + 1, 64)
        )
        self._auto_edge_budget = min(
            cap, _round_up(total_cap // DENSE_THRESHOLD_DENOM + 1, 64)
        )
        self._full_ids_budget = self._n
        self._full_edge_budget = max(cap, 1)

    # -- graph shape --------------------------------------------------------
    @property
    def n(self) -> int:
        return self._n

    @property
    def m(self) -> int:
        return self._m

    @property
    def degrees(self) -> jax.Array:
        return self.aux.deg_total

    @property
    def weights(self) -> Optional[jax.Array]:
        """The pool's value lane ((S, cap) float32), or None."""
        return self.sg.pool.vals

    @property
    def weighted_degrees(self) -> jax.Array:
        if self.sg.pool.vals is None:
            return self.aux.deg_total.astype(self.ops.float_dtype)
        if self._wdeg is None:
            self._wdeg = _sharded_weighted_degrees(
                self.aux.offsets, self.aux.evalid, self.sg.pool.vals,
                dtype=self.ops.float_dtype,
            )
        return self._wdeg

    @property
    def resident_nbytes(self) -> int:
        """Device bytes held per snapshot (pool + aux) — the raw side of
        the BYTES bench comparison."""
        return cz.pytree_nbytes(self.sg.pool) + cz.pytree_nbytes(self.aux)

    # -- frontiers ----------------------------------------------------------
    def frontier_from_ids(self, ids) -> JaxVertexSubset:
        mask = jnp.zeros(self._n, dtype=bool).at[jnp.asarray(ids)].set(True)
        return JaxVertexSubset(mask)

    def frontier_from_dense(self, mask) -> JaxVertexSubset:
        return JaxVertexSubset(jnp.asarray(mask, dtype=bool))

    def _budgets(self, mode: str) -> Tuple[int, int]:
        if mode == "sparse":
            return self._full_ids_budget, self._full_edge_budget
        return self._auto_ids_budget, self._auto_edge_budget

    # -- edgeMap ------------------------------------------------------------
    def edge_map(
        self,
        U: JaxVertexSubset,
        F: Callable,
        C: Callable,
        state,
        direction_optimize: bool = True,
        mode: str = "auto",
    ) -> Tuple[JaxVertexSubset, object]:
        if mode == "auto" and not direction_optimize:
            mode = "sparse"
        ids_b, edge_b = self._budgets(mode)
        state, out = _sharded_edge_map_step(
            self.aux.offsets,
            self.sg.pool.data,
            self.aux.src_c,
            self.aux.dst_c,
            self.aux.evalid,
            self.aux.degrees,
            jnp.int32(self._m),
            self.sg.pool.vals,
            U.dense,
            state,
            F=F,
            C=C,
            mode=mode,
            n=self._n,
            ids_budget=ids_b,
            edge_budget=edge_b,
            ops=self.ops,
            mesh=self.mesh,
            weighted=self.sg.pool.vals is not None,
        )
        return JaxVertexSubset(out), state

    # -- dense semiring reduce ---------------------------------------------
    def edge_map_reduce(self, values: jax.Array) -> jax.Array:
        return self.edge_map_reduce_batch(values[None, :])[0]

    def edge_map_reduce_batch(self, values: jax.Array) -> jax.Array:
        out = _sharded_reduce_batch(
            self.aux.src_by_dst,
            self.aux.valid_by_dst,
            self.aux.dst_offsets,
            self.aux.w_by_dst,
            jnp.asarray(values),
            n=self._n,
            mesh=self.mesh,
            weighted=self.aux.w_by_dst is not None,
            dtype=self.ops.float_dtype,
        )
        return out.astype(jnp.asarray(values).dtype)

    # -- in-trace batched drivers ------------------------------------------
    def bfs_batch(self, sources) -> Tuple[jax.Array, jax.Array]:
        padded, B = JaxEngine._quantized_sources(sources)
        parents, depths = bfs_batch_sharded(
            self.aux.offsets,
            self.sg.pool.data,
            self.aux.src_c,
            self.aux.dst_c,
            self.aux.evalid,
            self.aux.degrees,
            self.aux.src_by_dst,
            self.aux.valid_by_dst,
            self.aux.dst_offsets,
            jnp.int32(self._m),
            padded,
            n=self._n,
            ids_budget=self._auto_ids_budget,
            edge_budget=self._auto_edge_budget,
            mesh=self.mesh,
        )
        return parents[:B], depths[:B]

    def bc_batch(self, sources) -> jax.Array:
        """Multi-source Brandes dependencies, one in-trace sharded driver
        (``algorithms.bc_multi`` dispatches here instead of running
        generic edge_map rounds)."""
        padded, B = JaxEngine._quantized_sources(sources)
        dep = bc_batch_sharded(
            self.aux.offsets,
            self.aux.src_c,
            self.aux.dst_c,
            self.aux.evalid,
            self.aux.src_by_dst,
            self.aux.valid_by_dst,
            self.aux.dst_offsets,
            padded,
            n=self._n,
            mesh=self.mesh,
            float_dtype=self.ops.float_dtype,
        )
        return dep[:B]

    def sssp_batch(self, sources) -> jax.Array:
        padded, B = JaxEngine._quantized_sources(sources)
        weighted = self.sg.pool.vals is not None
        dist = sssp_batch_sharded(
            self.aux.offsets,
            self.sg.pool.data,
            self.aux.src_c,
            self.aux.dst_c,
            self.aux.evalid,
            self.aux.degrees,
            self.aux.src_by_dst,
            self.aux.valid_by_dst,
            self.aux.dst_offsets,
            self.sg.pool.vals if weighted else None,
            self.aux.w_by_dst if weighted else None,
            jnp.int32(self._m),
            padded,
            n=self._n,
            ids_budget=self._auto_ids_budget,
            edge_budget=self._auto_edge_budget,
            mesh=self.mesh,
            weighted=weighted,
            float_dtype=self.ops.float_dtype,
        )
        return dist[:B]

    def sssp_batch_from(self, dist0, frontier0, unit: bool = False) -> jax.Array:
        """Warm-start (min, +) relaxation from arbitrary initial state
        (see ``sssp_batch_sharded_from``) — the incremental BFS/SSSP
        driver on the sharded pool."""
        dist0, frontier0, B = JaxEngine._quantized_state(dist0, frontier0)
        weighted = self.sg.pool.vals is not None and not unit
        dist = sssp_batch_sharded_from(
            self.aux.offsets,
            self.sg.pool.data,
            self.aux.src_c,
            self.aux.dst_c,
            self.aux.evalid,
            self.aux.degrees,
            self.aux.src_by_dst,
            self.aux.valid_by_dst,
            self.aux.dst_offsets,
            self.sg.pool.vals if weighted else None,
            self.aux.w_by_dst if weighted else None,
            jnp.int32(self._m),
            jnp.asarray(dist0, self.ops.float_dtype),
            jnp.asarray(frontier0),
            n=self._n,
            ids_budget=self._auto_ids_budget,
            edge_budget=self._auto_edge_budget,
            mesh=self.mesh,
            weighted=weighted,
            unit=unit,
            float_dtype=self.ops.float_dtype,
        )
        return dist[:B]

    # -- vertexMap ----------------------------------------------------------
    def vertex_map(self, U: JaxVertexSubset, Pred: Callable, state) -> JaxVertexSubset:
        keep = Pred(self.ops, state, jnp.arange(self._n, dtype=jnp.int32))
        return JaxVertexSubset(U.dense & keep)

    def to_host(self, x) -> np.ndarray:
        from .base import HOST_SYNCS

        HOST_SYNCS.bump()
        return np.asarray(x)


# ---------------------------------------------------------------------------
# compressed sharded backend: queries over CompressedShardedGraph
# ---------------------------------------------------------------------------


class CompressedShardAux(NamedTuple):
    """Per-shard derived state for ``CompressedShardedEngine`` — the
    sharded counterpart of ``jax_backend.CompressedAux``.

    The two O(cap) int lanes of ``ShardAux`` that dominate its footprint
    (``dst_sorted``, ``src_by_dst``) are chunk-compressed per shard row;
    ``valid_by_dst`` collapses to one count per row (valid slots are the
    sorted prefix).  The O(S·n) arrays stay raw.  Every leaf keeps the
    (n_shards, ...) layout so ``P('shard', ...)`` specs still apply.
    """

    dst_sorted_c: cz.ChunkedStream  # (S, ...) destinations ascending
    srcbd_c: cz.ChunkedStream  # (S, ...) sources permuted dst-major
    dst_offsets: jax.Array  # int32[S, n+1]
    degrees: jax.Array  # int32[S, n]
    deg_total: jax.Array  # int32[n]
    m_valid: jax.Array  # int32[S] valid slots per shard row
    w_by_dst: Optional[jax.Array] = None  # float32[S, cap] dst-major


@functools.partial(jax.jit, static_argnums=(1, 2))
def shard_aux_compressed(
    cp: CompressedShardedPool, n: int, aux_hi_cap: Optional[int] = None
) -> CompressedShardAux:
    """One jit: decompress -> ``shard_aux`` -> re-compress the big int
    lanes (vmapped per shard row, so GSPMD keeps the encode shard-local).
    The uncompressed aux is a transient of this trace.  An adaptive pool
    gets adaptive aux lanes with the pool's hi capacity, overridable via
    ``aux_hi_cap`` (the engine retries at full capacity when only the
    aux permutation lanes overflow the inherited plane)."""
    p = _decompress_pool_impl(cp)
    aux = shard_aux(p, n)
    width, k = cp.dst.width, cp.dst.k
    if cp.dst.hi is not None:
        hc = cp.dst.hi.shape[-2] if aux_hi_cap is None else aux_hi_cap
        enc = jax.vmap(lambda v: cz._encode_adaptive_impl(v, hc, k))
    else:
        enc = jax.vmap(lambda v: cz._encode_impl(v, width, k))
    return CompressedShardAux(
        dst_sorted_c=enc(aux.dst_sorted),
        srcbd_c=enc(aux.src_by_dst),
        dst_offsets=aux.dst_offsets,
        degrees=aux.degrees,
        deg_total=aux.deg_total,
        m_valid=aux.evalid.sum(axis=1).astype(jnp.int32),
        w_by_dst=aux.w_by_dst,
    )


def _inflate_sharded(cp: CompressedShardedPool, caux: CompressedShardAux, n: int):
    """Trace-level inflate: (pool, aux) -> (ShardedPool, ShardAux) inside
    the caller's jit — the sharded analogue of ``jax_backend._inflate``.
    Forward lanes (clipped endpoints, validity) are recomputed from the
    decoded keys (cheaper than storing them); the dst-major permutation
    lanes decode from their streams (recomputing them would redo the
    per-row sort the aux exists to amortize).  All per-row, so the decode
    stays shard-local under GSPMD."""
    p = _decompress_pool_impl(cp)
    cap = p.data.shape[1]

    def row(drow, nrow):
        src = (drow >> 32).astype(jnp.int32)
        dst = (drow & 0xFFFFFFFF).astype(jnp.int32)
        valid = jnp.arange(cap) < nrow
        evalid = valid & (dst >= 0) & (dst < n)
        return (
            jnp.clip(src, 0, max(n - 1, 0)),
            jnp.clip(dst, 0, max(n - 1, 0)),
            evalid,
        )

    src_c, dst_c, evalid = jax.vmap(row)(p.data, p.n)
    aux = ShardAux(
        offsets=cp.offsets,
        src_c=src_c,
        dst_c=dst_c,
        evalid=evalid,
        degrees=caux.degrees,
        deg_total=caux.deg_total,
        dst_sorted=cz.decode_stream(caux.dst_sorted_c),
        src_by_dst=cz.decode_stream(caux.srcbd_c),
        valid_by_dst=jnp.arange(cap)[None, :] < caux.m_valid[:, None],
        dst_offsets=caux.dst_offsets,
        w_by_dst=caux.w_by_dst,
    )
    return p, aux


@functools.partial(
    jax.jit,
    static_argnames=(
        "F", "C", "mode", "n", "ids_budget", "edge_budget", "ops", "mesh", "weighted",
    ),
)
def _sharded_edge_map_step_compressed(
    cp, caux, m, U, state, *,
    F, C, mode, n, ids_budget, edge_budget, ops, mesh, weighted,
):
    p, aux = _inflate_sharded(cp, caux, n)
    return _sharded_edge_map_step(
        aux.offsets, p.data, aux.src_c, aux.dst_c, aux.evalid, aux.degrees,
        m, p.vals if weighted else None, U, state,
        F=F, C=C, mode=mode, n=n,
        ids_budget=ids_budget, edge_budget=edge_budget,
        ops=ops, mesh=mesh, weighted=weighted,
    )


@functools.partial(
    jax.jit, static_argnames=("n", "ids_budget", "edge_budget", "mesh")
)
def bfs_batch_sharded_compressed(
    cp, caux, m, sources, *, n, ids_budget, edge_budget, mesh
):
    p, aux = _inflate_sharded(cp, caux, n)
    return bfs_batch_sharded(
        aux.offsets, p.data, aux.src_c, aux.dst_c, aux.evalid, aux.degrees,
        aux.src_by_dst, aux.valid_by_dst, aux.dst_offsets, m, sources,
        n=n, ids_budget=ids_budget, edge_budget=edge_budget, mesh=mesh,
    )


@functools.partial(jax.jit, static_argnames=("n", "mesh", "float_dtype"))
def bc_batch_sharded_compressed(
    cp, caux, sources, *, n, mesh, float_dtype=jnp.float32
):
    p, aux = _inflate_sharded(cp, caux, n)
    return bc_batch_sharded(
        aux.offsets, aux.src_c, aux.dst_c, aux.evalid,
        aux.src_by_dst, aux.valid_by_dst, aux.dst_offsets, sources,
        n=n, mesh=mesh, float_dtype=float_dtype,
    )


@functools.partial(
    jax.jit,
    static_argnames=("n", "ids_budget", "edge_budget", "mesh", "weighted", "float_dtype"),
)
def sssp_batch_sharded_compressed(
    cp, caux, m, sources, *,
    n, ids_budget, edge_budget, mesh, weighted, float_dtype=jnp.float32,
):
    p, aux = _inflate_sharded(cp, caux, n)
    return sssp_batch_sharded(
        aux.offsets, p.data, aux.src_c, aux.dst_c, aux.evalid, aux.degrees,
        aux.src_by_dst, aux.valid_by_dst, aux.dst_offsets,
        p.vals if weighted else None,
        aux.w_by_dst if weighted else None,
        m, sources,
        n=n, ids_budget=ids_budget, edge_budget=edge_budget, mesh=mesh,
        weighted=weighted, float_dtype=float_dtype,
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "n", "ids_budget", "edge_budget", "mesh", "weighted", "unit", "float_dtype"
    ),
)
def sssp_batch_sharded_from_compressed(
    cp, caux, m, dist0, frontier0, *,
    n, ids_budget, edge_budget, mesh, weighted, unit=False,
    float_dtype=jnp.float32,
):
    p, aux = _inflate_sharded(cp, caux, n)
    return sssp_batch_sharded_from(
        aux.offsets, p.data, aux.src_c, aux.dst_c, aux.evalid, aux.degrees,
        aux.src_by_dst, aux.valid_by_dst, aux.dst_offsets,
        p.vals if weighted else None,
        aux.w_by_dst if weighted else None,
        m, dist0, frontier0,
        n=n, ids_budget=ids_budget, edge_budget=edge_budget, mesh=mesh,
        weighted=weighted, unit=unit, float_dtype=float_dtype,
    )


def _reduce_partial_compressed(
    anch, dl, pos, add, hi, wide, mv, bounds, wbd, values_b, n_pad, dtype
):
    """Per-device partial of the (+, x) reduce with the src gather lane
    decoded INSIDE the shard-local function — the sharded half of the
    fused-decode contract (the sharded reduce is a segmented row-sum, not
    the Pallas kernel, so 'inside the kernel' here means inside the
    shard_map body where the operand never exists uncompressed outside
    this trace).  ``hi``/``wide`` are the adaptive-width leaves (None on
    fixed-width streams); the per-row decode handles the width select."""
    no_spill = jnp.zeros((), bool)

    def one(anch_r, dl_r, pos_r, add_r, hi_r, wide_r, mv_r, brow, wrow):
        srow = cz.decode_rows(
            cz.ChunkedStream(
                anch_r, dl_r, pos_r, add_r, no_spill, hi=hi_r, wide=wide_r
            )
        ).reshape(-1)
        vrow = jnp.arange(srow.shape[0]) < mv_r
        msg = jnp.where(vrow[None, :], values_b[:, srow], 0.0).astype(dtype)
        if wrow is not None:
            msg = msg * wrow[None, :].astype(dtype)
        return _segsum_rows(msg, brow)

    def vone(a, d, p, v, h, wd, c, b, w=None):
        return one(a, d, p, v, h, wd, c, b, w)

    if hi is None:
        fone = lambda a, d, p, v, c, b, w=None: one(a, d, p, v, None, None, c, b, w)
        if wbd is None:
            parts = jax.vmap(lambda a, d, p, v, c, b: fone(a, d, p, v, c, b))(
                anch, dl, pos, add, mv, bounds
            )
        else:
            parts = jax.vmap(fone)(anch, dl, pos, add, mv, bounds, wbd)
    else:
        if wbd is None:
            parts = jax.vmap(
                lambda a, d, p, v, h, wd, c, b: vone(a, d, p, v, h, wd, c, b)
            )(anch, dl, pos, add, hi, wide, mv, bounds)
        else:
            parts = jax.vmap(vone)(anch, dl, pos, add, hi, wide, mv, bounds, wbd)
    partial = parts.sum(axis=0)  # (B, n)
    padded = jnp.pad(partial, ((0, 0), (0, n_pad - partial.shape[1])))
    return jax.lax.psum_scatter(padded, AXIS, scatter_dimension=1, tiled=True)


@functools.partial(jax.jit, static_argnames=("n", "mesh", "weighted", "dtype"))
def _sharded_reduce_batch_compressed(
    srcbd_c,  # cz.ChunkedStream, (S, ...) leaves
    m_valid,  # int32[S]
    dst_offsets,  # int32[S, n+1]
    w_by_dst,  # float32[S, cap] or None
    values_b,  # (B, n) replicated value rows
    *,
    n: int,
    mesh: Mesh,
    weighted: bool,
    dtype,
):
    n_pad = _round_up(max(n, 1), mesh.shape[AXIS])
    adaptive = srcbd_c.hi is not None
    if adaptive:
        # hi is (S, H, CHUNK): shard axis leads, rest replicated per row
        stream = (
            srcbd_c.anchors, srcbd_c.deltas, srcbd_c.ovf_pos, srcbd_c.ovf_add,
            srcbd_c.hi, srcbd_c.wide,
        )
        stream_specs = (_SPEC2,) * 4 + (P(AXIS, None, None), _SPEC2)
    else:
        stream = (srcbd_c.anchors, srcbd_c.deltas, srcbd_c.ovf_pos, srcbd_c.ovf_add)
        stream_specs = (_SPEC2,) * 4
    ns = len(stream)

    def local(*args):
        s, rest = args[:ns], args[ns:]
        hi_l, wide_l = (s[4], s[5]) if adaptive else (None, None)
        if weighted:
            c, b, w, x = rest
        else:
            (c, b, x), w = rest, None
        return _reduce_partial_compressed(
            s[0], s[1], s[2], s[3], hi_l, wide_l, c, b, w, x, n_pad, dtype
        )

    if weighted:
        out = _shard_map(
            local,
            mesh=mesh,
            in_specs=stream_specs + (P(AXIS), _SPEC2, _SPEC2, P()),
            out_specs=P(None, AXIS),
            check_rep=False,
        )(*stream, m_valid, dst_offsets, w_by_dst, values_b)
    else:
        out = _shard_map(
            local,
            mesh=mesh,
            in_specs=stream_specs + (P(AXIS), _SPEC2, P()),
            out_specs=P(None, AXIS),
            check_rep=False,
        )(*stream, m_valid, dst_offsets, values_b)
    return out[:, :n]


@functools.partial(jax.jit, static_argnames=("n", "dtype"))
def _sharded_weighted_degrees_compressed(cp, *, n, dtype):
    p = _decompress_pool_impl(cp)
    cap = p.data.shape[1]

    def row(drow, nrow):
        dst = (drow & 0xFFFFFFFF).astype(jnp.int32)
        return (jnp.arange(cap) < nrow) & (dst >= 0) & (dst < n)

    evalid = jax.vmap(row)(p.data, p.n)
    return _sharded_weighted_degrees(cp.offsets, evalid, p.vals, dtype)


class CompressedShardedEngine(ShardedEngine):
    """``ShardedEngine`` served from a chunk-compressed resident pool.

    Holds a ``CompressedShardedPool`` + ``CompressedShardAux``; every
    query step inflates per shard row inside its own jit (decoded rows
    are transients of the trace) and then runs the exact raw shard_map
    step — same collective schedule, same wire contract, compressed HBM
    residency.  Frontier helpers / budgets / vertexMap are inherited;
    only the data-touching dispatch targets differ.
    """

    def __init__(
        self,
        csg: CompressedShardedGraph,
        aux: Optional[CompressedShardAux] = None,
        mesh: Optional[Mesh] = None,
        float_dtype=None,
    ):
        self.csg = csg
        self._n = csg.n
        self.mesh = pool_mesh(csg.n_shards) if mesh is None else mesh
        if csg.n_shards % self.mesh.shape[AXIS] != 0:
            raise ValueError(
                f"n_shards={csg.n_shards} must be a multiple of the mesh "
                f"size {self.mesh.shape[AXIS]}"
            )
        self._m = graph_num_edges(csg)  # one device read per engine build
        self.ops = ShardedOps(jnp.float32 if float_dtype is None else float_dtype)
        self.caux = (
            shard_aux_compressed(csg.pool, csg.n) if aux is None else aux
        )
        self._wdeg = None
        # Spill check: construction already syncs (graph_num_edges), so
        # reading the flag rows here is free — a spilled stream would
        # silently mis-decode every query.
        pool_spilled = bool(np.asarray(csg.pool.dst.spill).any())
        aux_spilled = bool(
            np.asarray(self.caux.dst_sorted_c.spill).any()
        ) or bool(np.asarray(self.caux.srcbd_c.spill).any())
        if (
            not pool_spilled and aux_spilled and aux is None
            and csg.pool.dst.hi is not None
        ):
            # Adaptive aux lanes inherited the pool's (exact-fit) hi
            # capacity but need more wide chunks; retry once at full
            # capacity before declaring a genuine escape-lane spill.
            R = csg.pool.dst.deltas.shape[-2]
            self.caux = shard_aux_compressed(csg.pool, csg.n, R)
            aux_spilled = bool(
                np.asarray(self.caux.dst_sorted_c.spill).any()
            ) or bool(np.asarray(self.caux.srcbd_c.spill).any())
        if pool_spilled or aux_spilled:
            raise ValueError(
                "compressed sharded stream spilled its escape lane; "
                "rebuild with a wider delta lane or keep the raw engine"
            )

        S = csg.n_shards
        cap = csg.pool.cap_per
        total_cap = S * cap
        self._auto_ids_budget = min(
            self._n, _round_up(total_cap // DENSE_THRESHOLD_DENOM + 1, 64)
        )
        self._auto_edge_budget = min(
            cap, _round_up(total_cap // DENSE_THRESHOLD_DENOM + 1, 64)
        )
        self._full_ids_budget = self._n
        self._full_edge_budget = max(cap, 1)

    @property
    def degrees(self) -> jax.Array:
        return self.caux.deg_total

    @property
    def weights(self) -> Optional[jax.Array]:
        return self.csg.pool.vals

    @property
    def weighted_degrees(self) -> jax.Array:
        if self.csg.pool.vals is None:
            return self.caux.deg_total.astype(self.ops.float_dtype)
        if self._wdeg is None:
            self._wdeg = _sharded_weighted_degrees_compressed(
                self.csg.pool, n=self._n, dtype=self.ops.float_dtype
            )
        return self._wdeg

    @property
    def resident_nbytes(self) -> int:
        return cz.pytree_nbytes(self.csg.pool) + cz.pytree_nbytes(self.caux)

    def edge_map(self, U, F, C, state, direction_optimize=True, mode="auto"):
        if mode == "auto" and not direction_optimize:
            mode = "sparse"
        ids_b, edge_b = self._budgets(mode)
        state, out = _sharded_edge_map_step_compressed(
            self.csg.pool, self.caux, jnp.int32(self._m), U.dense, state,
            F=F, C=C, mode=mode, n=self._n,
            ids_budget=ids_b, edge_budget=edge_b,
            ops=self.ops, mesh=self.mesh,
            weighted=self.csg.pool.vals is not None,
        )
        return JaxVertexSubset(out), state

    def edge_map_reduce_batch(self, values: jax.Array) -> jax.Array:
        out = _sharded_reduce_batch_compressed(
            self.caux.srcbd_c,
            self.caux.m_valid,
            self.caux.dst_offsets,
            self.caux.w_by_dst,
            jnp.asarray(values),
            n=self._n,
            mesh=self.mesh,
            weighted=self.caux.w_by_dst is not None,
            dtype=self.ops.float_dtype,
        )
        return out.astype(jnp.asarray(values).dtype)

    def bfs_batch(self, sources) -> Tuple[jax.Array, jax.Array]:
        padded, B = JaxEngine._quantized_sources(sources)
        parents, depths = bfs_batch_sharded_compressed(
            self.csg.pool, self.caux, jnp.int32(self._m), padded,
            n=self._n,
            ids_budget=self._auto_ids_budget,
            edge_budget=self._auto_edge_budget,
            mesh=self.mesh,
        )
        return parents[:B], depths[:B]

    def bc_batch(self, sources) -> jax.Array:
        padded, B = JaxEngine._quantized_sources(sources)
        dep = bc_batch_sharded_compressed(
            self.csg.pool, self.caux, padded,
            n=self._n, mesh=self.mesh, float_dtype=self.ops.float_dtype,
        )
        return dep[:B]

    def sssp_batch(self, sources) -> jax.Array:
        padded, B = JaxEngine._quantized_sources(sources)
        dist = sssp_batch_sharded_compressed(
            self.csg.pool, self.caux, jnp.int32(self._m), padded,
            n=self._n,
            ids_budget=self._auto_ids_budget,
            edge_budget=self._auto_edge_budget,
            mesh=self.mesh,
            weighted=self.csg.pool.vals is not None,
            float_dtype=self.ops.float_dtype,
        )
        return dist[:B]

    def sssp_batch_from(self, dist0, frontier0, unit: bool = False) -> jax.Array:
        dist0, frontier0, B = JaxEngine._quantized_state(dist0, frontier0)
        weighted = self.csg.pool.vals is not None and not unit
        dist = sssp_batch_sharded_from_compressed(
            self.csg.pool, self.caux, jnp.int32(self._m),
            jnp.asarray(dist0, self.ops.float_dtype), jnp.asarray(frontier0),
            n=self._n,
            ids_budget=self._auto_ids_budget,
            edge_budget=self._auto_edge_budget,
            mesh=self.mesh,
            weighted=weighted,
            unit=unit,
            float_dtype=self.ops.float_dtype,
        )
        return dist[:B]
