"""Unified edgeMap traversal engine: one algorithm text, three backends.

See ``base.py`` for the backend contract, ``numpy_backend`` /
``jax_backend`` / ``sharded_backend`` for the substrates, and
``algorithms`` for the backend-generic BFS / PageRank / CC / SSSP / BC.

Quick start::

    from repro.core import graph as G, flat_graph as fg
    from repro.core import sharded_pool as sp
    from repro.core.traversal import make_engine, algorithms as talg

    eng_np = make_engine(G.flat_snapshot(g))       # CPU / FlatSnapshot
    eng_jx = make_engine(fg.from_edges(n, edges))  # TPU / FlatGraph
    eng_sh = make_engine(sp.graph_from_edges(n, edges))  # mesh / ShardedGraph
    assert (talg.bfs(eng_np, 0) >= 0).sum() == (talg.bfs(eng_jx, 0) >= 0).sum()
"""
from __future__ import annotations

from . import algorithms
from .base import (
    DENSE_THRESHOLD_DENOM,
    HOST_SYNCS,
    TRACES,
    ArrayOps,
    Counter,
    TraversalEngine,
    dense_threshold,
)
from .numpy_backend import (
    NumpyEngine,
    VertexSubset,
    edge_map,
    engine_of,
    from_dense,
    from_ids,
    gather_csr,
)

__all__ = [
    "DENSE_THRESHOLD_DENOM",
    "ArrayOps",
    "TraversalEngine",
    "dense_threshold",
    "NumpyEngine",
    "JaxEngine",
    "CompressedEngine",
    "ShardedEngine",
    "CompressedShardedEngine",
    "VertexSubset",
    "edge_map",
    "engine_of",
    "from_dense",
    "from_ids",
    "gather_csr",
    "algorithms",
    "make_engine",
    "flat_graph_of",
    "FLAT_REBUILDS",
    "ENGINE_BUILDS",
    "HOST_SYNCS",
    "TRACES",
]


# Counts FlatSnapshot -> FlatGraph host rebuilds (the O(m) path the
# resident mirror exists to avoid).  Tests spy on ``count`` to assert
# the mirror's engine path never falls back to a rebuild.
FLAT_REBUILDS = Counter()

# Counts engine constructions in the version-pinned engine cache
# (``AspenStream._engine_for``).  Tests spy on ``count`` to assert a
# mixed-kind batch against one version builds its engine exactly once.
ENGINE_BUILDS = Counter()


def __getattr__(name):
    # JaxEngine / ShardedEngine import jax + the Pallas kernel wrappers;
    # keep the numpy-only path importable without paying that (lazy).
    if name == "JaxEngine":
        from .jax_backend import JaxEngine

        return JaxEngine
    if name == "CompressedEngine":
        from .jax_backend import CompressedEngine

        return CompressedEngine
    if name == "ShardedEngine":
        from .sharded_backend import ShardedEngine

        return ShardedEngine
    if name == "CompressedShardedEngine":
        from .sharded_backend import CompressedShardedEngine

        return CompressedShardedEngine
    raise AttributeError(name)


def make_engine(obj, backend: str | None = None) -> TraversalEngine:
    """Engine for a snapshot object, dispatched on type (or forced by
    ``backend`` in {"numpy", "jax", "sharded"}).

    Accepts a ``FlatGraph`` (-> JaxEngine), a ``ShardedGraph``
    (-> ShardedEngine), anything with the FlatSnapshot protocol
    (-> NumpyEngine), or a tree-level ``Graph`` (snapshotted first;
    backend selects the substrate).
    """
    from ..flat_graph import CompressedPool, FlatGraph
    from ..graph import Graph, flat_snapshot
    from ..sharded_pool import CompressedShardedGraph, ShardedGraph

    if backend not in (None, "numpy", "jax", "sharded"):
        raise ValueError(
            f"unknown backend {backend!r}; expected 'numpy', 'jax' or 'sharded'"
        )
    if isinstance(obj, CompressedPool):
        if backend in ("numpy", "sharded"):
            raise TypeError("CompressedPool is jax-native; decompress first")
        from .jax_backend import CompressedEngine

        return CompressedEngine(obj)
    if isinstance(obj, CompressedShardedGraph):
        if backend in ("numpy", "jax"):
            raise TypeError("CompressedShardedGraph is sharded-native")
        from .sharded_backend import CompressedShardedEngine

        return CompressedShardedEngine(obj)
    if isinstance(obj, ShardedGraph):
        if backend in ("numpy", "jax"):
            raise TypeError("ShardedGraph is sharded-native; pass backend='sharded'")
        from .sharded_backend import ShardedEngine

        return ShardedEngine(obj)
    if isinstance(obj, FlatGraph):
        if backend == "numpy":
            raise TypeError("FlatGraph is jax-native; build a FlatSnapshot for numpy")
        if backend == "sharded":
            return make_engine(sharded_graph_of_flat(obj))
        from .jax_backend import JaxEngine

        return JaxEngine(obj)
    if isinstance(obj, Graph):
        snap = flat_snapshot(obj)
        if backend in ("jax", "sharded"):
            return make_engine(_flat_graph_of(snap), backend=backend)
        return engine_of(snap)
    if backend in ("jax", "sharded"):
        return make_engine(_flat_graph_of(obj), backend=backend)
    return engine_of(obj)


def sharded_graph_of_flat(g, n_shards: int | None = None):
    """FlatGraph -> ShardedGraph: range-partition the packed-key pool
    (and its value lane) over the mesh.  Host-side O(m); streams keep a
    resident sharded mirror precisely so queries never pay this per
    version."""
    from ..flat_graph import to_edge_array, to_weight_array
    from ..sharded_pool import graph_from_edges

    return graph_from_edges(
        g.n, to_edge_array(g), n_shards=n_shards, weights=to_weight_array(g)
    )


def flat_graph_of(snap):
    """FlatSnapshot -> FlatGraph (host-side O(m) CSR rebuild; weighted
    snapshots carry their per-edge values into the pool's value array).

    This is the *fallback* substrate conversion — streams keep a
    resident mirror precisely so queries never pay this per version
    (``FLAT_REBUILDS`` counts how often anyone still does)."""
    import numpy as np

    from ..flat_graph import from_edges

    FLAT_REBUILDS.bump()
    offsets, nbrs = gather_csr(snap, np.arange(snap.n, dtype=np.int64))
    srcs = np.repeat(np.arange(snap.n, dtype=np.int64), np.diff(offsets))
    weights = (
        snap.edge_weights(srcs, nbrs)
        if getattr(snap, "weighted", False)
        else None
    )
    return from_edges(snap.n, np.stack([srcs, nbrs], axis=1), weights=weights)


_flat_graph_of = flat_graph_of  # backward-compatible alias
