"""vertexSubset + edgeMap with direction optimization (paper §2, §5, §5.1).

Ligra semantics, vectorized over numpy: the map/cond functions take
*arrays* instead of scalars (the CPU-parallel-for of the paper maps to
vector lanes here — the same adaptation the TPU level makes explicit).

  F(us, vs) -> bool mask   applied to edges (us[i] -> vs[i]); may mutate
                           algorithm state arrays (e.g. parents)
  C(vs)     -> bool mask   filter on targets

``edge_map`` dispatches sparse vs dense traversal by the Ligra/Beamer
threshold |U| + deg(U) > (m / 20) (paper §5.1 "Direction Optimization").
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import numpy as np

from .graph import FlatSnapshot

DENSE_THRESHOLD_DENOM = 20


class VertexSubset(NamedTuple):
    n: int
    ids: Optional[np.ndarray] = None  # sparse form (sorted, unique)
    dense: Optional[np.ndarray] = None  # bool[n]

    @property
    def size(self) -> int:
        return int(self.dense.sum()) if self.dense is not None else self.ids.size

    def to_sparse(self) -> np.ndarray:
        return self.ids if self.ids is not None else np.flatnonzero(self.dense)

    def to_dense(self) -> np.ndarray:
        if self.dense is not None:
            return self.dense
        d = np.zeros(self.n, dtype=bool)
        d[self.ids] = True
        return d

    @property
    def empty(self) -> bool:
        return self.size == 0


def from_ids(n: int, ids) -> VertexSubset:
    return VertexSubset(n, ids=np.unique(np.asarray(ids, dtype=np.int64)))


def from_dense(mask: np.ndarray) -> VertexSubset:
    return VertexSubset(mask.size, dense=mask)


def gather_csr(snap: FlatSnapshot, vs: np.ndarray):
    """Concatenate neighbor lists of ``vs``: (offsets[len(vs)+1], nbrs).

    This is the chunk-decode work: O(sum deg) with O(log n + deg) per
    vertex on the tree level, O(deg) via the flat snapshot (paper §5.1).
    """
    lists = [snap.neighbors(int(v)) for v in vs]
    offsets = np.zeros(len(lists) + 1, dtype=np.int64)
    if lists:
        np.cumsum([l.size for l in lists], out=offsets[1:])
        nbrs = np.concatenate(lists) if offsets[-1] else np.empty(0, np.int64)
    else:
        nbrs = np.empty(0, np.int64)
    return offsets, nbrs


def edge_map(
    snap: FlatSnapshot,
    U: VertexSubset,
    F: Callable[[np.ndarray, np.ndarray], np.ndarray],
    C: Callable[[np.ndarray], np.ndarray],
    m: Optional[int] = None,
    direction_optimize: bool = True,
    F_dense: Optional[Callable] = None,
) -> VertexSubset:
    """EDGEMAP(G, U, F, C) -> U' (paper §2).

    ``F_dense(vs_candidates, offsets, nbrs_in_U_mask)`` may be supplied
    for algorithms whose dense form differs (e.g. BFS picks one parent).
    """
    n = snap.n
    if U.empty:
        return VertexSubset(n, ids=np.empty(0, dtype=np.int64))
    us = U.to_sparse()
    deg_u = sum(snap.degree(int(u)) for u in us)
    if m is None:
        m = sum(snap.degree(v) for v in range(n))
    if direction_optimize and (us.size + deg_u) > max(1, m // DENSE_THRESHOLD_DENOM):
        return _edge_map_dense(snap, U, F, C, F_dense)
    return _edge_map_sparse(snap, us, F, C, n)


def _edge_map_sparse(snap, us, F, C, n) -> VertexSubset:
    offsets, nbrs = gather_csr(snap, us)
    if nbrs.size == 0:
        return VertexSubset(n, ids=np.empty(0, dtype=np.int64))
    srcs = np.repeat(us, np.diff(offsets))
    keep = C(nbrs)
    if keep.any():
        hit = F(srcs[keep], nbrs[keep])
        out = nbrs[keep][hit]
    else:
        out = np.empty(0, dtype=np.int64)
    return VertexSubset(n, ids=np.unique(out))


def _edge_map_dense(snap, U, F, C, F_dense) -> VertexSubset:
    n = snap.n
    in_u = U.to_dense()
    candidates = np.flatnonzero(C(np.arange(n, dtype=np.int64)))
    if candidates.size == 0:
        return VertexSubset(n, ids=np.empty(0, dtype=np.int64))
    offsets, nbrs = gather_csr(snap, candidates)
    nbr_in_u = in_u[nbrs] if nbrs.size else np.empty(0, bool)
    if F_dense is not None:
        out_mask = F_dense(candidates, offsets, nbrs, nbr_in_u)
    else:
        # generic dense: v joins U' if F fires on any (u in U) -> v edge
        srcs = nbrs
        dsts = np.repeat(candidates, np.diff(offsets))
        sel = nbr_in_u
        fired = np.zeros(nbrs.size, dtype=bool)
        if sel.any():
            fired[sel] = F(srcs[sel], dsts[sel])
        seg = np.repeat(np.arange(candidates.size), np.diff(offsets))
        out_mask = np.zeros(candidates.size, dtype=bool)
        np.logical_or.at(out_mask, seg[fired], True)
    return VertexSubset(n, ids=candidates[out_mask])
