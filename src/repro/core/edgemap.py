"""Compatibility shim: vertexSubset + edgeMap moved to
``repro.core.traversal`` (the backend-unified engine).

This module keeps the original import surface — ``VertexSubset``,
``from_ids``, ``from_dense``, ``gather_csr``, and the Ligra-signature
``edge_map(snap, U, F, C)`` — all now backed by the numpy traversal
backend.  New code should use ``repro.core.traversal`` directly (and
gets the jax/TPU backend for free via ``make_engine``).
"""
from __future__ import annotations

from .traversal.base import DENSE_THRESHOLD_DENOM
from .traversal.numpy_backend import (
    NumpyEngine,
    VertexSubset,
    edge_map,
    engine_of,
    from_dense,
    from_ids,
    gather_csr,
)

__all__ = [
    "DENSE_THRESHOLD_DENOM",
    "NumpyEngine",
    "VertexSubset",
    "edge_map",
    "engine_of",
    "from_dense",
    "from_ids",
    "gather_csr",
]
