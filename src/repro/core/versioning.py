"""Version maintenance: acquire / set / release (paper §6, [8]).

The paper solves the *version maintenance problem* with a lock-free
algorithm because CPU writers and readers race on the version list.  In
our single-controller runtime the writer is the Python host, so a host
mutex around the (tiny, O(1)) version-list operations preserves the exact
interface and serializability guarantees; lock-freedom addresses a race
that cannot occur here (documented in DESIGN.md §2).

Guarantees preserved from the paper:
  * any number of concurrent readers acquire snapshots without blocking
    the writer or each other (they hold immutable structure);
  * a single writer ACQUIREs, builds functionally, SETs — the new version
    becomes atomically visible to subsequent acquires;
  * RELEASE refcounts; a version is garbage-collected (dropped from the
    live list, letting shared tree nodes be reclaimed) when its refcount
    reaches zero and it is not current — strict serializability holds
    because every query runs against exactly one immutable version.

Dual representations (DESIGN.md §6): a version may carry *auxiliary*
representations of the same logical graph alongside the primary one —
e.g. the C-tree ``Graph`` paired with its device-resident ``FlatGraph``
mirror.  ``set(graph, aux=...)`` publishes them atomically as ONE
version, so readers always observe a consistent (graph, aux) pair and
pick their substrate at acquire time with zero rebuild.  Each version
also owns a ``cache`` dict (version-pinned derived state, e.g. traversal
engines keyed by backend); the cache — and everything in it — dies with
the version when the last reference drops, so engine caches can never
leak across the version lifecycle or outlive their snapshot.
"""
from __future__ import annotations

import threading
from typing import Any, Callable, Dict, Generic, List, Optional, TypeVar

import numpy as np

G = TypeVar("G")

DELTA = "delta"  # aux key of the per-version update record


class Delta:
    """The edge batch one published version applied to its predecessor.

    Versions are purely functional, so the diff between consecutive
    stamps is exactly the batch the writer applied — recording it at
    publish time makes the diff a first-class artifact the incremental
    query path (warm-start PageRank, incremental CC/BFS/SSSP) consumes
    instead of recomputing from scratch.  Stored per version in
    ``Version.aux[DELTA]``, so it is GC'd with its version like every
    other aux representation.

    ``ins``/``dels`` are directed int64[k, 2] edge arrays exactly as
    applied (a symmetric insert records both directions); ``ins_w`` is
    the per-inserted-edge value lane or None.  A version published
    through a non-edge path (vertex-set ops, raw ``vg`` writes) carries
    no delta at all, which ``delta_between`` reports as None — the
    full-recompute signal.
    """

    __slots__ = ("ins", "ins_w", "dels", "__weakref__")

    def __init__(
        self,
        ins: Optional[np.ndarray] = None,
        ins_w: Optional[np.ndarray] = None,
        dels: Optional[np.ndarray] = None,
    ):
        empty = np.empty((0, 2), dtype=np.int64)
        self.ins = empty if ins is None else np.asarray(ins, np.int64).reshape(-1, 2)
        self.dels = empty if dels is None else np.asarray(dels, np.int64).reshape(-1, 2)
        self.ins_w = None if ins_w is None else np.asarray(ins_w, np.float32).reshape(-1)

    @property
    def empty(self) -> bool:
        return self.ins.shape[0] == 0 and self.dels.shape[0] == 0

    @property
    def has_deletions(self) -> bool:
        return self.dels.shape[0] > 0

    @property
    def endpoints(self) -> np.ndarray:
        """Unique vertex ids touched by the batch (the perturbation /
        seed-frontier set of the incremental algorithms)."""
        return np.unique(np.concatenate([self.ins.ravel(), self.dels.ravel()]))

    @property
    def nbytes(self) -> int:
        w = 0 if self.ins_w is None else self.ins_w.nbytes
        return self.ins.nbytes + self.dels.nbytes + w

    @classmethod
    def concat(cls, parts: "List[Delta]") -> "Delta":
        """Compose deltas across consecutive stamps.  Inserts and
        deletes are unioned independently — conservative for the
        incremental consumers (they relax over the NEW snapshot, so
        seeds/dirty sets may only be supersets)."""
        if not parts:
            return cls()
        ins = np.concatenate([p.ins for p in parts])
        dels = np.concatenate([p.dels for p in parts])
        if any(p.ins_w is not None for p in parts):
            ins_w = np.concatenate(
                [
                    p.ins_w
                    if p.ins_w is not None
                    else np.ones(p.ins.shape[0], np.float32)
                    for p in parts
                ]
            )
        else:
            ins_w = None
        return cls(ins=ins, ins_w=ins_w, dels=dels)

    def __repr__(self):
        return f"Delta(ins={self.ins.shape[0]}, dels={self.dels.shape[0]})"


class Version(Generic[G]):
    __slots__ = ("graph", "aux", "cache", "stamp", "_refcount", "__weakref__")

    def __init__(self, graph: G, stamp: int, aux: Optional[Dict[str, Any]] = None):
        self.graph = graph
        self.aux: Dict[str, Any] = aux if aux is not None else {}
        self.cache: Dict[Any, Any] = {}
        self.stamp = stamp
        self._refcount = 0

    def __repr__(self):
        tags = ",".join(sorted(self.aux)) or "-"
        return f"Version(stamp={self.stamp}, rc={self._refcount}, aux={tags})"


class VersionedGraph(Generic[G]):
    """Multi-version single-writer / multi-reader graph store."""

    def __init__(self, initial: G, aux: Optional[Dict[str, Any]] = None):
        self._lock = threading.Lock()
        self._stamp = 0
        self._versions: Dict[int, Version[G]] = {}
        self._current = Version(initial, 0, aux)
        self._versions[0] = self._current
        self._collected = 0

    # -- reader interface ---------------------------------------------------
    def acquire(self) -> Version[G]:
        """Atomically grab the current version (refcount++)."""
        with self._lock:
            v = self._current
            v._refcount += 1
            return v

    def release(self, v: Version[G]) -> bool:
        """Drop a reference; returns True if this was the last one and the
        version was garbage-collected.

        Idempotent past zero: releasing a version whose refcount has
        already drained (a double-release) is a no-op returning False
        rather than driving the count negative — a negative count would
        keep the version collectible forever while a later acquire/
        release pair races it, corrupting the live list.  (A
        double-release *while other readers still hold the version* is
        indistinguishable from a legitimate release without per-acquire
        tokens; the clamp closes the corrupting case.)"""
        with self._lock:
            if v._refcount <= 0:
                return False
            v._refcount -= 1
            if v._refcount == 0 and v is not self._current:
                self._versions.pop(v.stamp, None)
                self._collected += 1
                return True
            return False

    # -- writer interface ---------------------------------------------------
    def set(self, graph: G, aux: Optional[Dict[str, Any]] = None) -> Version[G]:
        """Publish a new version (single writer).  ``aux`` rides along
        atomically: readers acquiring the new version see the primary
        graph and every auxiliary representation together."""
        with self._lock:
            self._stamp += 1
            nv = Version(graph, self._stamp, aux)
            old = self._current
            self._current = nv
            self._versions[self._stamp] = nv
            if old._refcount == 0:
                self._versions.pop(old.stamp, None)
                self._collected += 1
            return nv

    def update(self, fn: Callable[[G], G]) -> Version[G]:
        """Writer transaction: acquire -> functional update -> set -> release."""
        v = self.acquire()
        try:
            return self.set(fn(v.graph))
        finally:
            self.release(v)

    def update_with_aux(
        self, fn: Callable[[Version[G]], "tuple[G, Optional[Dict[str, Any]]]"]
    ) -> Version[G]:
        """Writer transaction over the full version: ``fn`` sees the held
        (graph, aux) pair and returns the next one — both published as a
        single atomic version."""
        v = self.acquire()
        try:
            graph, aux = fn(v)
            return self.set(graph, aux)
        finally:
            self.release(v)

    # -- deltas --------------------------------------------------------------
    def delta_between(self, v_old: Version[G], v_new: Version[G]) -> Optional[Delta]:
        """The composed edge delta taking ``v_old``'s graph to
        ``v_new``'s, or None when it cannot be derived — any hop already
        collected, or any hop published without a delta record (vertex
        ops, raw writes).  None is the full-recompute signal; an
        incremental consumer holding ``v_old`` (subscriptions and the
        result cache's carry-forward do) always finds the one-hop chain
        intact because the hop's delta lives on ``v_new`` itself."""
        return self.delta_between_stamps(v_old.stamp, v_new.stamp)

    def delta_between_stamps(self, old_stamp: int, new_stamp: int) -> Optional[Delta]:
        """``delta_between`` by stamp: the same chain walk for callers
        that hold stamps rather than version objects (version-holding
        callers get the same liveness guarantee through the stamps —
        only the delta records between the two are consulted)."""
        if new_stamp < old_stamp:
            return None
        if new_stamp == old_stamp:
            return Delta()
        with self._lock:
            parts: List[Delta] = []
            for s in range(old_stamp + 1, new_stamp + 1):
                v = self._versions.get(s)
                if v is None:
                    return None  # hop collected: chain broken
                d = v.aux.get(DELTA)
                if not isinstance(d, Delta):
                    return None  # hop published without a delta record
                parts.append(d)
        return Delta.concat(parts)

    # -- introspection -------------------------------------------------------
    @property
    def current_stamp(self) -> int:
        with self._lock:
            return self._current.stamp

    def live_versions(self) -> int:
        with self._lock:
            return len(self._versions)

    def collected_versions(self) -> int:
        with self._lock:
            return self._collected
