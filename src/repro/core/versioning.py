"""Version maintenance: acquire / set / release (paper §6, [8]).

The paper solves the *version maintenance problem* with a lock-free
algorithm because CPU writers and readers race on the version list.  In
our single-controller runtime the writer is the Python host, so a host
mutex around the (tiny, O(1)) version-list operations preserves the exact
interface and serializability guarantees; lock-freedom addresses a race
that cannot occur here (documented in DESIGN.md §2).

Guarantees preserved from the paper:
  * any number of concurrent readers acquire snapshots without blocking
    the writer or each other (they hold immutable structure);
  * a single writer ACQUIREs, builds functionally, SETs — the new version
    becomes atomically visible to subsequent acquires;
  * RELEASE refcounts; a version is garbage-collected (dropped from the
    live list, letting shared tree nodes be reclaimed) when its refcount
    reaches zero and it is not current — strict serializability holds
    because every query runs against exactly one immutable version.

Dual representations (DESIGN.md §6): a version may carry *auxiliary*
representations of the same logical graph alongside the primary one —
e.g. the C-tree ``Graph`` paired with its device-resident ``FlatGraph``
mirror.  ``set(graph, aux=...)`` publishes them atomically as ONE
version, so readers always observe a consistent (graph, aux) pair and
pick their substrate at acquire time with zero rebuild.  Each version
also owns a ``cache`` dict (version-pinned derived state, e.g. traversal
engines keyed by backend); the cache — and everything in it — dies with
the version when the last reference drops, so engine caches can never
leak across the version lifecycle or outlive their snapshot.
"""
from __future__ import annotations

import threading
from typing import Any, Callable, Dict, Generic, Optional, TypeVar

G = TypeVar("G")


class Version(Generic[G]):
    __slots__ = ("graph", "aux", "cache", "stamp", "_refcount", "__weakref__")

    def __init__(self, graph: G, stamp: int, aux: Optional[Dict[str, Any]] = None):
        self.graph = graph
        self.aux: Dict[str, Any] = aux if aux is not None else {}
        self.cache: Dict[Any, Any] = {}
        self.stamp = stamp
        self._refcount = 0

    def __repr__(self):
        tags = ",".join(sorted(self.aux)) or "-"
        return f"Version(stamp={self.stamp}, rc={self._refcount}, aux={tags})"


class VersionedGraph(Generic[G]):
    """Multi-version single-writer / multi-reader graph store."""

    def __init__(self, initial: G, aux: Optional[Dict[str, Any]] = None):
        self._lock = threading.Lock()
        self._stamp = 0
        self._versions: Dict[int, Version[G]] = {}
        self._current = Version(initial, 0, aux)
        self._versions[0] = self._current
        self._collected = 0

    # -- reader interface ---------------------------------------------------
    def acquire(self) -> Version[G]:
        """Atomically grab the current version (refcount++)."""
        with self._lock:
            v = self._current
            v._refcount += 1
            return v

    def release(self, v: Version[G]) -> bool:
        """Drop a reference; returns True if this was the last one and the
        version was garbage-collected."""
        with self._lock:
            v._refcount -= 1
            assert v._refcount >= 0, "release without acquire"
            if v._refcount == 0 and v is not self._current:
                self._versions.pop(v.stamp, None)
                self._collected += 1
                return True
            return False

    # -- writer interface ---------------------------------------------------
    def set(self, graph: G, aux: Optional[Dict[str, Any]] = None) -> Version[G]:
        """Publish a new version (single writer).  ``aux`` rides along
        atomically: readers acquiring the new version see the primary
        graph and every auxiliary representation together."""
        with self._lock:
            self._stamp += 1
            nv = Version(graph, self._stamp, aux)
            old = self._current
            self._current = nv
            self._versions[self._stamp] = nv
            if old._refcount == 0:
                self._versions.pop(old.stamp, None)
                self._collected += 1
            return nv

    def update(self, fn: Callable[[G], G]) -> Version[G]:
        """Writer transaction: acquire -> functional update -> set -> release."""
        v = self.acquire()
        try:
            return self.set(fn(v.graph))
        finally:
            self.release(v)

    def update_with_aux(
        self, fn: Callable[[Version[G]], "tuple[G, Optional[Dict[str, Any]]]"]
    ) -> Version[G]:
        """Writer transaction over the full version: ``fn`` sees the held
        (graph, aux) pair and returns the next one — both published as a
        single atomic version."""
        v = self.acquire()
        try:
            graph, aux = fn(v)
            return self.set(graph, aux)
        finally:
            self.release(v)

    # -- introspection -------------------------------------------------------
    @property
    def current_stamp(self) -> int:
        with self._lock:
            return self._current.stamp

    def live_versions(self) -> int:
        with self._lock:
            return len(self._versions)

    def collected_versions(self) -> int:
        with self._lock:
            return self._collected
