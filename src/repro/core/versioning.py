"""Version maintenance: acquire / set / release (paper §6, [8]).

The paper solves the *version maintenance problem* with a lock-free
algorithm because CPU writers and readers race on the version list.  In
our single-controller runtime the writer is the Python host, so a host
mutex around the (tiny, O(1)) version-list operations preserves the exact
interface and serializability guarantees; lock-freedom addresses a race
that cannot occur here (documented in DESIGN.md §2).

Guarantees preserved from the paper:
  * any number of concurrent readers acquire snapshots without blocking
    the writer or each other (they hold immutable structure);
  * a single writer ACQUIREs, builds functionally, SETs — the new version
    becomes atomically visible to subsequent acquires;
  * RELEASE refcounts; a version is garbage-collected (dropped from the
    live list, letting shared tree nodes be reclaimed) when its refcount
    reaches zero and it is not current — strict serializability holds
    because every query runs against exactly one immutable version.
"""
from __future__ import annotations

import threading
from typing import Any, Callable, Dict, Generic, List, Optional, Tuple, TypeVar

G = TypeVar("G")


class Version(Generic[G]):
    __slots__ = ("graph", "stamp", "_refcount")

    def __init__(self, graph: G, stamp: int):
        self.graph = graph
        self.stamp = stamp
        self._refcount = 0

    def __repr__(self):
        return f"Version(stamp={self.stamp}, rc={self._refcount})"


class VersionedGraph(Generic[G]):
    """Multi-version single-writer / multi-reader graph store."""

    def __init__(self, initial: G):
        self._lock = threading.Lock()
        self._stamp = 0
        self._versions: Dict[int, Version[G]] = {}
        self._current = Version(initial, 0)
        self._versions[0] = self._current
        self._collected = 0

    # -- reader interface ---------------------------------------------------
    def acquire(self) -> Version[G]:
        """Atomically grab the current version (refcount++)."""
        with self._lock:
            v = self._current
            v._refcount += 1
            return v

    def release(self, v: Version[G]) -> bool:
        """Drop a reference; returns True if this was the last one and the
        version was garbage-collected."""
        with self._lock:
            v._refcount -= 1
            assert v._refcount >= 0, "release without acquire"
            if v._refcount == 0 and v is not self._current:
                self._versions.pop(v.stamp, None)
                self._collected += 1
                return True
            return False

    # -- writer interface ---------------------------------------------------
    def set(self, graph: G) -> Version[G]:
        """Publish a new version (single writer)."""
        with self._lock:
            self._stamp += 1
            nv = Version(graph, self._stamp)
            old = self._current
            self._current = nv
            self._versions[self._stamp] = nv
            if old._refcount == 0:
                self._versions.pop(old.stamp, None)
                self._collected += 1
            return nv

    def update(self, fn: Callable[[G], G]) -> Version[G]:
        """Writer transaction: acquire -> functional update -> set -> release."""
        v = self.acquire()
        try:
            return self.set(fn(v.graph))
        finally:
            self.release(v)

    # -- introspection -------------------------------------------------------
    @property
    def current_stamp(self) -> int:
        with self._lock:
            return self._current.stamp

    def live_versions(self) -> int:
        with self._lock:
            return len(self._versions)

    def collected_versions(self) -> int:
        with self._lock:
            return self._collected
