"""Graphs as trees of C-trees (paper §5) — the faithful Aspen core.

The vertex-tree is a purely-functional augmented treap (``pam``) mapping
``vertex_id -> edge C-tree``; the augmentation tracks total edge count so
``num_edges`` is O(1).  Batch updates follow §5 exactly: sort the batch,
build a C-tree per touched source, MULTIINSERT into the vertex-tree with
UNION as the value-combiner.

A *flat snapshot* (§5.1) is an array of per-vertex edge-tree references —
O(n) work to build, after which edge access is O(deg(v)) like CSR.
"""
from __future__ import annotations

from typing import List, NamedTuple, Optional

import numpy as np

from . import ctree as ct
from .pam import Node, TreeModule

# vertex-tree: value = edge C-tree; aug = #edges
_VMOD = TreeModule(
    aug_of=lambda k, et: ct.ctree_size(et) if et is not None else 0,
    combine=lambda a, b: a + b,
    zero=0,
)

# weight-tree: purely-functional map packed (src<<32|dst) -> float edge
# value (the PaC-tree "collections carry associated values" side of the
# design, DESIGN.md §8).  It versions with the graph for free — every
# snapshot shares structure with its ancestors — and stays None on
# unweighted graphs (no storage, no maintenance work).
_WMOD = TreeModule()


def _pack_edges(edges: np.ndarray) -> np.ndarray:
    edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    return (edges[:, 0] << 32) | edges[:, 1]


class Graph(NamedTuple):
    """An immutable graph snapshot (one version).

    ``wtree`` optionally maps packed edge keys to per-edge float values
    (weights); ``None`` means unweighted.  A weighted graph keeps the
    weight map exactly in sync with the edge set: inserts overwrite the
    value of an existing edge, deletes drop it.
    """

    vtree: Node  # treap: vertex id -> CTree of neighbor ids
    b: int = ct.DEFAULT_B
    seed: int = ct.DEFAULT_SEED
    wtree: Node = None  # treap: packed (src<<32|dst) -> float weight


def empty(b: int = ct.DEFAULT_B, seed: int = ct.DEFAULT_SEED) -> Graph:
    return Graph(None, b, seed)


def num_vertices(g: Graph) -> int:
    from .pam import size

    return size(g.vtree)


def num_edges(g: Graph) -> int:
    """O(1) via the vertex-tree augmentation (paper §5)."""
    return _VMOD.aug(g.vtree)


def find_vertex(g: Graph, v: int) -> Optional[ct.CTree]:
    return _VMOD.find(g.vtree, v)


def degree(g: Graph, v: int) -> int:
    et = find_vertex(g, v)
    return ct.ctree_size(et) if et is not None else 0


# ---------------------------------------------------------------------------
# construction & batch updates (paper §5 "Batch Updates")
# ---------------------------------------------------------------------------


def _group_batch(edges: np.ndarray):
    """Sort a (k, 2) batch by (src, dst) and yield (src, dst_array)."""
    edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    if edges.shape[0] == 0:
        return
    order = np.lexsort((edges[:, 1], edges[:, 0]))
    edges = edges[order]
    srcs, starts = np.unique(edges[:, 0], return_index=True)
    bounds = np.append(starts, edges.shape[0])
    for i, s in enumerate(srcs.tolist()):
        yield int(s), edges[bounds[i] : bounds[i + 1], 1]


def _wtree_insert(g: Graph, edges: np.ndarray, weights: Optional[np.ndarray]) -> Node:
    """New weight-tree after an insert batch: overwrite-on-duplicate
    across batches, first-occurrence-wins within one batch (matching
    the flat pool's dedup).  A weight-less batch against a weighted
    graph gets unit weights."""
    if weights is None and g.wtree is None:
        return None
    keys = _pack_edges(edges)
    if weights is None:
        w = np.ones(keys.size, dtype=np.float64)
    else:
        w = np.asarray(weights, dtype=np.float64).reshape(-1)
        if w.size != keys.size:
            raise ValueError("one weight per edge")
    _, first = np.unique(keys, return_index=True)
    entries = [(int(keys[i]), float(w[i])) for i in first]
    return _WMOD.multi_insert(
        g.wtree, entries, combine_values=lambda old, new: new
    )


def build_graph(
    n: int,
    edges: np.ndarray,
    b: int = ct.DEFAULT_B,
    seed: int = ct.DEFAULT_SEED,
    weights: Optional[np.ndarray] = None,
) -> Graph:
    """BuildGraph: n isolated vertices + a batch of directed edges."""
    per_vertex = {s: d for s, d in _group_batch(edges)}
    entries = []
    for v in range(n):
        dsts = per_vertex.get(v)
        et = ct.build(dsts, b, seed) if dsts is not None else ct.empty(b, seed)
        entries.append((v, et))
    g = Graph(_VMOD.build_sorted(entries), b, seed)
    if weights is None:
        return g
    return g._replace(wtree=_wtree_insert(g, edges, weights))


def insert_edges(g: Graph, edges: np.ndarray, weights: Optional[np.ndarray] = None) -> Graph:
    """InsertEdges: functional batch insert (new snapshot returned).

    Sort batch -> per-source C-trees -> MultiInsert with UNION combiner
    (paper §5).  Vertices not yet present are created.  ``weights``
    attaches one value per batch edge (overwriting the value of an edge
    already present); passing weights to an unweighted graph upgrades
    it — edges inserted before the upgrade read as unit weight.
    """
    updates = [
        (s, ct.build(dsts, g.b, g.seed)) for s, dsts in _group_batch(edges)
    ]
    vt = _VMOD.multi_insert(
        g.vtree,
        updates,
        combine_values=lambda old, new: ct.union(old, new)
        if old is not None
        else new,
    )
    return Graph(vt, g.b, g.seed, _wtree_insert(g, edges, weights))


def delete_edges(g: Graph, edges: np.ndarray) -> Graph:
    """DeleteEdges: functional batch delete via DIFFERENCE (a deleted
    edge drops its weight)."""
    removals = {s: dsts for s, dsts in _group_batch(edges)}
    updates = []
    for s, dsts in removals.items():
        old = _VMOD.find(g.vtree, s)
        if old is None:
            continue
        updates.append((s, ct.multi_delete(old, dsts)))
    vt = _VMOD.multi_insert(g.vtree, updates, combine_values=lambda old, new: new)
    wt = g.wtree
    if wt is not None:
        wt = _WMOD.multi_delete(wt, [int(k) for k in _pack_edges(edges)])
    return Graph(vt, g.b, g.seed, wt)


def insert_vertices(g: Graph, vs: np.ndarray) -> Graph:
    updates = [(int(v), ct.empty(g.b, g.seed)) for v in np.asarray(vs)]
    vt = _VMOD.multi_insert(g.vtree, updates, combine_values=lambda old, new: old)
    return Graph(vt, g.b, g.seed, g.wtree)


def delete_vertices(g: Graph, vs: np.ndarray) -> Graph:
    """Remove vertices (and their out-edges; callers of symmetric graphs
    pass both endpoints' edges to delete_edges first)."""
    vt = _VMOD.multi_delete(g.vtree, [int(v) for v in np.asarray(vs)])
    wt = g.wtree
    if wt is not None:
        # purge weights whose src was removed (dst-side weights were
        # dropped by the delete_edges call symmetric callers issue).
        # Vertex v's packed keys are the contiguous range
        # [v<<32, (v+1)<<32), so each purge is two ordered splits —
        # O(log W) per vertex, not a full weight-tree walk.
        for v in sorted(int(x) for x in np.asarray(vs)):
            lo, hi = v << 32, (v + 1) << 32
            left, _, rest = _WMOD.split(wt, lo)
            _, at_hi, right = _WMOD.split(rest, hi)
            if at_hi is not None:  # hi is the NEXT vertex's first key
                right = _WMOD.join(None, hi, at_hi, right)
            wt = _WMOD.join2(left, right)
    return Graph(vt, g.b, g.seed, wt)


# ---------------------------------------------------------------------------
# flat snapshots (paper §5.1)
# ---------------------------------------------------------------------------


class FlatSnapshot:
    """Array of per-vertex edge-tree refs: O(1) vertex access (§5.1).

    Building is O(n) work / O(log n) depth in the paper (one traversal);
    the functional trees underneath stay shared and immutable, so a flat
    snapshot can be taken concurrently with updates.

    The snapshot caches its degree vector and total directed edge count
    ``m`` on first access: the direction-optimization threshold in the
    traversal engine consults ``m`` every edgeMap call, and the old
    per-query O(n) python degree loop was a measurable constant cost.

    Weighted graphs additionally hand the snapshot their weight-tree:
    ``edge_weights(srcs, dsts)`` answers vectorized per-edge lookups
    from a sorted (keys, values) export materialized LAZILY on first
    use (so unweighted queries — and weighted streams that never run a
    weighted algorithm on this snapshot — stay O(n) to snapshot).
    """

    __slots__ = (
        "edge_trees", "n", "_degrees", "_m", "_engine", "_wtree", "_wexport"
    )

    def __init__(
        self,
        edge_trees: List[Optional[ct.CTree]],
        n: int,
        wtree: Node = None,
    ):
        self.edge_trees = edge_trees
        self.n = n
        self._degrees: Optional[np.ndarray] = None
        self._m: Optional[int] = None
        self._engine = None  # cached traversal NumpyEngine (CSR caches)
        self._wtree = wtree
        self._wexport = None  # lazy (sorted packed keys, float64 values)

    @property
    def weighted(self) -> bool:
        return self._wtree is not None

    def _weight_export(self):
        if self._wexport is None:
            pairs = list(_WMOD.iter_entries(self._wtree))  # in-order: sorted
            keys = np.fromiter((k for k, _ in pairs), np.int64, count=len(pairs))
            vals = np.fromiter((v for _, v in pairs), np.float64, count=len(pairs))
            self._wexport = (keys, vals)
        return self._wexport

    def edge_weights(self, srcs: np.ndarray, dsts: np.ndarray) -> np.ndarray:
        """float64 weight per (src, dst) pair; edges missing from the
        weight map (pre-upgrade inserts) read as unit weight."""
        keys, vals = self._weight_export()
        q = (np.asarray(srcs, np.int64) << 32) | np.asarray(dsts, np.int64)
        if keys.size == 0:
            return np.ones(q.shape, np.float64)
        idx = np.minimum(np.searchsorted(keys, q), keys.size - 1)
        return np.where(keys[idx] == q, vals[idx], 1.0)

    def neighbors(self, v: int) -> np.ndarray:
        et = self.edge_trees[v]
        return ct.to_array(et) if et is not None else np.empty(0, np.int64)

    def degree(self, v: int) -> int:
        et = self.edge_trees[v]
        return ct.ctree_size(et) if et is not None else 0

    @property
    def degrees(self) -> np.ndarray:
        """Cached degree vector (each entry O(1) via the C-tree size
        augmentation; materialized once per snapshot)."""
        if self._degrees is None:
            self._degrees = np.fromiter(
                (self.degree(v) for v in range(self.n)), np.int64, count=self.n
            )
        return self._degrees

    @property
    def m(self) -> int:
        """Total directed edge count (cached degree sum)."""
        if self._m is None:
            self._m = int(self.degrees.sum())
        return self._m


def flat_snapshot(g: Graph) -> FlatSnapshot:
    pairs = list(_VMOD.iter_entries(g.vtree))
    max_v = pairs[-1][0] if pairs else -1
    refs: List[Optional[ct.CTree]] = [None] * (max_v + 1)
    for v, et in pairs:
        refs[v] = et
    return FlatSnapshot(refs, max_v + 1, wtree=g.wtree)


def snapshot_nbytes(s: FlatSnapshot) -> int:
    """8 bytes per vertex pointer (paper Table 2 'Flat Snap.')."""
    return 8 * s.n


def graph_nbytes(g: Graph, compressed: bool = True, chunked: bool = True) -> int:
    """Aspen memory model (paper §7.1).

    chunked=False emulates the 'Aspen Uncomp.' column: every edge is its
    own 32B functional tree node, every vertex a 48B node.
    compressed=False with chunked=True is the 'No DE' column (8B/element
    chunks).
    """
    VERTEX_NODE = 56 if chunked else 48  # §7.1: 56B with prefix pointers
    total = 0
    for v, et in _VMOD.iter_entries(g.vtree):
        total += VERTEX_NODE
        if et is None:
            continue
        if chunked:
            total += ct.nbytes(et, compressed=compressed)
        else:
            total += ct.uncompressed_tree_bytes(et)
    return total
