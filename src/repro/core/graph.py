"""Graphs as trees of C-trees (paper §5) — the faithful Aspen core.

The vertex-tree is a purely-functional augmented treap (``pam``) mapping
``vertex_id -> edge C-tree``; the augmentation tracks total edge count so
``num_edges`` is O(1).  Batch updates follow §5 exactly: sort the batch,
build a C-tree per touched source, MULTIINSERT into the vertex-tree with
UNION as the value-combiner.

A *flat snapshot* (§5.1) is an array of per-vertex edge-tree references —
O(n) work to build, after which edge access is O(deg(v)) like CSR.
"""
from __future__ import annotations

from typing import List, NamedTuple, Optional

import numpy as np

from . import ctree as ct
from .pam import Node, TreeModule

# vertex-tree: value = edge C-tree; aug = #edges
_VMOD = TreeModule(
    aug_of=lambda k, et: ct.ctree_size(et) if et is not None else 0,
    combine=lambda a, b: a + b,
    zero=0,
)


class Graph(NamedTuple):
    """An immutable graph snapshot (one version)."""

    vtree: Node  # treap: vertex id -> CTree of neighbor ids
    b: int = ct.DEFAULT_B
    seed: int = ct.DEFAULT_SEED


def empty(b: int = ct.DEFAULT_B, seed: int = ct.DEFAULT_SEED) -> Graph:
    return Graph(None, b, seed)


def num_vertices(g: Graph) -> int:
    from .pam import size

    return size(g.vtree)


def num_edges(g: Graph) -> int:
    """O(1) via the vertex-tree augmentation (paper §5)."""
    return _VMOD.aug(g.vtree)


def find_vertex(g: Graph, v: int) -> Optional[ct.CTree]:
    return _VMOD.find(g.vtree, v)


def degree(g: Graph, v: int) -> int:
    et = find_vertex(g, v)
    return ct.ctree_size(et) if et is not None else 0


# ---------------------------------------------------------------------------
# construction & batch updates (paper §5 "Batch Updates")
# ---------------------------------------------------------------------------


def _group_batch(edges: np.ndarray):
    """Sort a (k, 2) batch by (src, dst) and yield (src, dst_array)."""
    edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    if edges.shape[0] == 0:
        return
    order = np.lexsort((edges[:, 1], edges[:, 0]))
    edges = edges[order]
    srcs, starts = np.unique(edges[:, 0], return_index=True)
    bounds = np.append(starts, edges.shape[0])
    for i, s in enumerate(srcs.tolist()):
        yield int(s), edges[bounds[i] : bounds[i + 1], 1]


def build_graph(n: int, edges: np.ndarray, b: int = ct.DEFAULT_B, seed: int = ct.DEFAULT_SEED) -> Graph:
    """BuildGraph: n isolated vertices + a batch of directed edges."""
    per_vertex = {s: d for s, d in _group_batch(edges)}
    entries = []
    for v in range(n):
        dsts = per_vertex.get(v)
        et = ct.build(dsts, b, seed) if dsts is not None else ct.empty(b, seed)
        entries.append((v, et))
    return Graph(_VMOD.build_sorted(entries), b, seed)


def insert_edges(g: Graph, edges: np.ndarray) -> Graph:
    """InsertEdges: functional batch insert (new snapshot returned).

    Sort batch -> per-source C-trees -> MultiInsert with UNION combiner
    (paper §5).  Vertices not yet present are created.
    """
    updates = [
        (s, ct.build(dsts, g.b, g.seed)) for s, dsts in _group_batch(edges)
    ]
    vt = _VMOD.multi_insert(
        g.vtree,
        updates,
        combine_values=lambda old, new: ct.union(old, new)
        if old is not None
        else new,
    )
    return Graph(vt, g.b, g.seed)


def delete_edges(g: Graph, edges: np.ndarray) -> Graph:
    """DeleteEdges: functional batch delete via DIFFERENCE."""
    removals = {s: dsts for s, dsts in _group_batch(edges)}
    updates = []
    for s, dsts in removals.items():
        old = _VMOD.find(g.vtree, s)
        if old is None:
            continue
        updates.append((s, ct.multi_delete(old, dsts)))
    vt = _VMOD.multi_insert(g.vtree, updates, combine_values=lambda old, new: new)
    return Graph(vt, g.b, g.seed)


def insert_vertices(g: Graph, vs: np.ndarray) -> Graph:
    updates = [(int(v), ct.empty(g.b, g.seed)) for v in np.asarray(vs)]
    vt = _VMOD.multi_insert(g.vtree, updates, combine_values=lambda old, new: old)
    return Graph(vt, g.b, g.seed)


def delete_vertices(g: Graph, vs: np.ndarray) -> Graph:
    """Remove vertices (and their out-edges; callers of symmetric graphs
    pass both endpoints' edges to delete_edges first)."""
    vt = _VMOD.multi_delete(g.vtree, [int(v) for v in np.asarray(vs)])
    return Graph(vt, g.b, g.seed)


# ---------------------------------------------------------------------------
# flat snapshots (paper §5.1)
# ---------------------------------------------------------------------------


class FlatSnapshot:
    """Array of per-vertex edge-tree refs: O(1) vertex access (§5.1).

    Building is O(n) work / O(log n) depth in the paper (one traversal);
    the functional trees underneath stay shared and immutable, so a flat
    snapshot can be taken concurrently with updates.

    The snapshot caches its degree vector and total directed edge count
    ``m`` on first access: the direction-optimization threshold in the
    traversal engine consults ``m`` every edgeMap call, and the old
    per-query O(n) python degree loop was a measurable constant cost.
    """

    __slots__ = ("edge_trees", "n", "_degrees", "_m", "_engine")

    def __init__(self, edge_trees: List[Optional[ct.CTree]], n: int):
        self.edge_trees = edge_trees
        self.n = n
        self._degrees: Optional[np.ndarray] = None
        self._m: Optional[int] = None
        self._engine = None  # cached traversal NumpyEngine (CSR caches)

    def neighbors(self, v: int) -> np.ndarray:
        et = self.edge_trees[v]
        return ct.to_array(et) if et is not None else np.empty(0, np.int64)

    def degree(self, v: int) -> int:
        et = self.edge_trees[v]
        return ct.ctree_size(et) if et is not None else 0

    @property
    def degrees(self) -> np.ndarray:
        """Cached degree vector (each entry O(1) via the C-tree size
        augmentation; materialized once per snapshot)."""
        if self._degrees is None:
            self._degrees = np.fromiter(
                (self.degree(v) for v in range(self.n)), np.int64, count=self.n
            )
        return self._degrees

    @property
    def m(self) -> int:
        """Total directed edge count (cached degree sum)."""
        if self._m is None:
            self._m = int(self.degrees.sum())
        return self._m


def flat_snapshot(g: Graph) -> FlatSnapshot:
    pairs = list(_VMOD.iter_entries(g.vtree))
    max_v = pairs[-1][0] if pairs else -1
    refs: List[Optional[ct.CTree]] = [None] * (max_v + 1)
    for v, et in pairs:
        refs[v] = et
    return FlatSnapshot(refs, max_v + 1)


def snapshot_nbytes(s: FlatSnapshot) -> int:
    """8 bytes per vertex pointer (paper Table 2 'Flat Snap.')."""
    return 8 * s.n


def graph_nbytes(g: Graph, compressed: bool = True, chunked: bool = True) -> int:
    """Aspen memory model (paper §7.1).

    chunked=False emulates the 'Aspen Uncomp.' column: every edge is its
    own 32B functional tree node, every vertex a 48B node.
    compressed=False with chunked=True is the 'No DE' column (8B/element
    chunks).
    """
    VERTEX_NODE = 56 if chunked else 48  # §7.1: 56B with prefix pointers
    total = 0
    for v, et in _VMOD.iter_entries(g.vtree):
        total += VERTEX_NODE
        if et is None:
            continue
        if chunked:
            total += ct.nbytes(et, compressed=compressed)
        else:
            total += ct.uncompressed_tree_bytes(et)
    return total
