"""TPU-native flat C-tree (the hardware adaptation of core/ctree.py).

A pointer treap is hostile to TPUs (no pointers under jit, dynamic shapes,
serial chasing).  The C-tree's *insight* — hash-canonical chunk boundaries
over a sorted pool — survives intact in flat form:

  data[capacity] : sorted element pool (padding = SENTINEL at the top)
  n              : valid-count scalar
  heads          : DERIVED, is_head(data) — never stored, recomputed by one
                   hash pass on the VPU (headness is canonical, paper §3.1)

All operations are fixed-shape jax ops: ``find`` is a searchsorted;
``union`` is either a concat-sort (baseline) or an O(n+k) rank-merge
(optimized; two searchsorteds + scatter — the TPU analogue of the paper's
leaf-level chunk merge); ``difference``/``intersect`` are membership masks
+ compaction.  Chunk compression (fixed-width packed deltas, the vbyte
adaptation) lives in ``chunks.pack_deltas`` for storage accounting and
``kernels/delta_decode`` for the on-device decode.

Capacity is static per jit trace; the host quantizes capacities to powers
of two so recompiles are O(log max_n) over a stream's lifetime.

Equivalence with the faithful C-tree (same elements, same heads, same
chunk boundaries) is property-tested in tests/test_flat_ctree.py.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .hash import is_head_jnp

SENTINEL32 = np.int32(np.iinfo(np.int32).max)
SENTINEL64 = np.int64(np.iinfo(np.int64).max)


def sentinel_for(dtype) -> int:
    return int(np.iinfo(np.dtype(dtype)).max)


class FlatCTree(NamedTuple):
    """Flat sorted pool with a valid count; a jax pytree (shardable).

    ``vals`` optionally carries ONE associated value per element (the
    PaC-tree key->value generalization): ``vals[i]`` belongs to
    ``data[i]`` and is permuted by every merge / compaction alongside
    its key.  ``vals is None`` is the plain-set layout — no value array
    is allocated and every operation traces exactly as before (the
    weighted branches below are Python-level, decided at trace time).

    Value semantics across set operations:
      * union (merge or sort): a batch element whose key already exists
        OVERWRITES the pool element's value (last-writer-wins per
        batch); within one batch the FIRST occurrence of a duplicate
        key wins (``from_array`` / ``from_device`` dedup keep-first).
      * difference: dropping a key drops its value.
    """

    data: jax.Array  # [capacity] sorted; data[n:] == SENTINEL
    n: jax.Array  # int32 scalar
    vals: jax.Array | None = None  # [capacity] associated values (pad 0)


def capacity(t: FlatCTree) -> int:
    return t.data.shape[0]


def empty(cap: int, dtype=jnp.int32) -> FlatCTree:
    return FlatCTree(
        jnp.full((cap,), sentinel_for(dtype), dtype=dtype), jnp.int32(0)
    )


def from_array(
    values: np.ndarray,
    cap: int | None = None,
    dtype=jnp.int32,
    vals: np.ndarray | None = None,
    val_dtype=jnp.float32,
) -> FlatCTree:
    """Host-side build: sort+dedup then pad to capacity.  ``vals``
    optionally attaches one value per element (duplicate keys keep the
    FIRST occurrence's value)."""
    raw = np.asarray(values)
    if vals is None:
        v = np.unique(raw)
        w = None
    else:
        v, first = np.unique(raw, return_index=True)
        w = np.asarray(vals, dtype=np.dtype(val_dtype)).reshape(-1)[first]
    if cap is None:
        cap = max(8, int(2 ** np.ceil(np.log2(max(v.size, 1) + 1))))
    assert v.size <= cap
    data = np.full(cap, sentinel_for(dtype), dtype=np.dtype(dtype))
    data[: v.size] = v
    if w is None:
        return FlatCTree(jnp.asarray(data), jnp.int32(v.size))
    wdata = np.zeros(cap, dtype=np.dtype(val_dtype))
    wdata[: v.size] = w
    return FlatCTree(jnp.asarray(data), jnp.int32(v.size), jnp.asarray(wdata))


def to_array(t: FlatCTree) -> np.ndarray:
    d = np.asarray(t.data)
    return d[: int(t.n)]


def to_val_array(t: FlatCTree) -> np.ndarray | None:
    """The valid prefix of the value array (None on plain sets)."""
    return None if t.vals is None else np.asarray(t.vals)[: int(t.n)]


@functools.partial(jax.jit, static_argnums=(1,))
def from_device(values: jax.Array, cap: int, vals: jax.Array | None = None) -> FlatCTree:
    """Device-side build: sort + dedup + compact, all under jit.

    ``values`` is a dense device array of raw (possibly duplicated,
    unsorted) elements; sentinel-valued slots are dropped, so a caller
    may pre-pad to a quantized shape.  The host never touches the data —
    this is the streaming ingest path (batches arrive device-resident
    and stay there).  ``vals`` rides along through a stable argsort, so
    the first occurrence of a duplicate key keeps its value (matching
    ``from_array``)."""
    if vals is None:
        v = jnp.sort(values.ravel())
        keep = _dedup_mask(v, jnp.int32(v.shape[0]))
        return _compact(v, keep, cap)
    order = jnp.argsort(values.ravel(), stable=True)
    v = values.ravel()[order]
    keep = _dedup_mask(v, jnp.int32(v.shape[0]))
    return _compact(v, keep, cap, vals=vals.ravel()[order])


# ---------------------------------------------------------------------------
# membership / find
# ---------------------------------------------------------------------------


@jax.jit
def member(t: FlatCTree, queries: jax.Array) -> jax.Array:
    """Vectorized Find: bool per query (padding-safe)."""
    idx = jnp.searchsorted(t.data, queries)
    idx = jnp.minimum(idx, t.data.shape[0] - 1)
    return (t.data[idx] == queries) & (queries != sentinel_for(t.data.dtype))


def find(t: FlatCTree, e: int) -> bool:
    return bool(member(t, jnp.asarray([e], dtype=t.data.dtype))[0])


# ---------------------------------------------------------------------------
# head / chunk structure (canonical, derived)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnums=(1, 2))
def head_mask(t: FlatCTree, b: int, seed: int) -> jax.Array:
    """is_head over valid elements (the one-pass VPU re-chunk)."""
    valid = jnp.arange(t.data.shape[0]) < t.n
    return is_head_jnp(t.data.astype(jnp.uint32), b, seed) & valid


@functools.partial(jax.jit, static_argnums=(1, 2))
def chunk_ids(t: FlatCTree, b: int, seed: int) -> jax.Array:
    """chunk id per slot; prefix = 0, tail of i-th head = i+1."""
    return jnp.cumsum(head_mask(t, b, seed).astype(jnp.int32))


def num_heads(t: FlatCTree, b: int, seed: int) -> int:
    return int(head_mask(t, b, seed).sum())


# ---------------------------------------------------------------------------
# batch union: baseline (sort) and optimized (rank-merge)
# ---------------------------------------------------------------------------


def _dedup_mask(sorted_data: jax.Array, n_total: jax.Array) -> jax.Array:
    keep = jnp.ones(sorted_data.shape, dtype=bool)
    keep = keep.at[1:].set(sorted_data[1:] != sorted_data[:-1])
    keep &= jnp.arange(sorted_data.shape[0]) < n_total
    keep &= sorted_data != sentinel_for(sorted_data.dtype)
    return keep


def _compact(
    values: jax.Array, keep: jax.Array, out_cap: int, vals: jax.Array | None = None
) -> FlatCTree:
    """Scatter kept values to the front of a fresh pool (associated
    values, when present, ride the same permutation)."""
    sent = sentinel_for(values.dtype)
    pos = jnp.cumsum(keep.astype(jnp.int32)) - 1
    pos = jnp.where(keep, pos, out_cap)  # dropped via OOB
    out = jnp.full((out_cap,), sent, dtype=values.dtype)
    out = out.at[pos].set(values, mode="drop")
    n_out = keep.sum().astype(jnp.int32)
    if vals is None:
        return FlatCTree(out, n_out)
    vout = jnp.zeros((out_cap,), dtype=vals.dtype).at[pos].set(vals, mode="drop")
    return FlatCTree(out, n_out, vout)


def _aligned_vals(t: FlatCTree, batch: FlatCTree):
    """(vals_a, vals_b) for a union, or (None, None) when both inputs
    are plain sets.  A mixed union is upgraded at trace time: the
    value-less side is materialized as unit weights (the streaming
    auto-upgrade — an unweighted pool receiving its first weighted
    batch, or a weighted pool receiving a weight-less batch)."""
    if t.vals is None and batch.vals is None:
        return None, None
    va = t.vals if t.vals is not None else jnp.ones(t.data.shape[0], batch.vals.dtype)
    vb = batch.vals if batch.vals is not None else jnp.ones(
        batch.data.shape[0], t.vals.dtype
    )
    return va, vb


@functools.partial(jax.jit, static_argnums=(2,))
def union_sort(t: FlatCTree, batch: FlatCTree, out_cap: int) -> FlatCTree:
    """Baseline MultiInsert: concat + sort + dedup + compact.

    O((n+k) log(n+k)) compares; one XLA sort. The paper-faithful analogue
    of rebuilding; kept as the reference and the §Perf 'before'.

    With associated values the sort becomes a stable argsort so values
    ride the permutation; a duplicated key keeps the BATCH value (the
    pool copy sorts first, and each kept slot reads the last value of
    its equal-run — runs are length <= 2 since both inputs are deduped).
    """
    va, vb = _aligned_vals(t, batch)
    if va is None:
        allv = jnp.sort(jnp.concatenate([t.data, batch.data]))
        keep = _dedup_mask(allv, t.n + batch.n)
        return _compact(allv, keep, out_cap)
    allk = jnp.concatenate([t.data, batch.data])
    order = jnp.argsort(allk, stable=True)
    allv = allk[order]
    vals = jnp.concatenate([va, vb])[order]
    keep = _dedup_mask(allv, t.n + batch.n)
    nxt_same = jnp.concatenate(
        [allv[1:] == allv[:-1], jnp.zeros((1,), dtype=bool)]
    )
    vals = jnp.where(nxt_same, jnp.roll(vals, -1), vals)  # batch overwrites
    return _compact(allv, keep, out_cap, vals=vals)


@functools.partial(jax.jit, static_argnums=(2,))
def union_merge(t: FlatCTree, batch: FlatCTree, out_cap: int) -> FlatCTree:
    """Optimized MultiInsert: O(n+k) rank-merge.

    Output position of a-element = own index + #unique-b-elements below it;
    of a kept b-element = #a-below + #kept-b-below.  Two searchsorteds and
    one scatter — bandwidth-bound, no sort network.  This mirrors the
    paper's Union leaf case (merge two chunks) applied to the whole pool.
    """
    a, b = t.data, batch.data
    sent = sentinel_for(a.dtype)
    ca, cb = a.shape[0], b.shape[0]
    valid_a = jnp.arange(ca) < t.n
    valid_b = jnp.arange(cb) < batch.n

    # which b are duplicates of an a element?
    ia = jnp.minimum(jnp.searchsorted(a, b), ca - 1)
    dup_b = (a[ia] == b) & valid_b
    keep_b = valid_b & ~dup_b
    kb_excl = jnp.cumsum(keep_b.astype(jnp.int32)) - keep_b  # exclusive prefix

    # positions
    ra = jnp.searchsorted(b, a)  # #b-entries < a[i] (valid b only: pad=max)
    kept_below_a = jnp.where(ra > 0, kb_excl[jnp.minimum(ra - 1, cb - 1)] +
                             keep_b[jnp.minimum(ra - 1, cb - 1)], 0)
    pos_a = jnp.arange(ca, dtype=jnp.int32) + kept_below_a.astype(jnp.int32)
    pos_a = jnp.where(valid_a, pos_a, out_cap)

    rb = jnp.searchsorted(a, b)  # #a < b[j]
    pos_b = rb.astype(jnp.int32) + kb_excl.astype(jnp.int32)
    pos_b = jnp.where(keep_b, pos_b, out_cap)

    out = jnp.full((out_cap,), sent, dtype=a.dtype)
    out = out.at[pos_a].set(a, mode="drop")
    out = out.at[pos_b].set(b, mode="drop")
    n_out = (t.n + keep_b.sum()).astype(jnp.int32)
    va, vb = _aligned_vals(t, batch)
    if va is None:
        return FlatCTree(out, n_out)
    # values ride the same two scatters; a duplicate b key lands its
    # value on the matched a slot (insert overwrites, PaC-tree style)
    vout = jnp.zeros((out_cap,), dtype=va.dtype)
    vout = vout.at[pos_a].set(va, mode="drop")
    vout = vout.at[pos_b].set(vb, mode="drop")
    pos_dup = jnp.where(dup_b, pos_a[ia], out_cap)
    vout = vout.at[pos_dup].set(vb, mode="drop")
    return FlatCTree(out, n_out, vout)


@functools.partial(jax.jit, static_argnums=(2,))
def difference(t: FlatCTree, batch: FlatCTree, out_cap: int) -> FlatCTree:
    """MultiDelete: drop elements of t found in batch; compact (a
    dropped key drops its associated value)."""
    drop = member(batch, t.data)
    valid = jnp.arange(t.data.shape[0]) < t.n
    return _compact(t.data, valid & ~drop, out_cap, vals=t.vals)


@functools.partial(jax.jit, static_argnums=(2,))
def intersect(t: FlatCTree, batch: FlatCTree, out_cap: int) -> FlatCTree:
    keep = member(batch, t.data) & (jnp.arange(t.data.shape[0]) < t.n)
    return _compact(t.data, keep, out_cap, vals=t.vals)


# ---------------------------------------------------------------------------
# host-side capacity policy
# ---------------------------------------------------------------------------


def grown_capacity(n_needed: int) -> int:
    """Power-of-two quantization: bounds jit recompiles to O(log max_n)."""
    return max(8, int(2 ** np.ceil(np.log2(n_needed + 1))))


def multi_insert(
    t: FlatCTree,
    values: np.ndarray,
    optimized: bool = True,
    vals: np.ndarray | None = None,
) -> FlatCTree:
    """Host-driven batch insert: build batch, pick capacity, run union."""
    batch = from_array(values, dtype=t.data.dtype, vals=vals)
    need = int(t.n) + int(batch.n)
    cap = max(capacity(t), grown_capacity(need))
    fn = union_merge if optimized else union_sort
    return fn(t, batch, cap)


def multi_delete(t: FlatCTree, values: np.ndarray) -> FlatCTree:
    batch = from_array(values, dtype=t.data.dtype)
    return difference(t, batch, capacity(t))
