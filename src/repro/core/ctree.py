"""Paper-faithful C-tree (paper §3–§4, Algorithms 1–3).

A C-tree over a set of integers is ``(tree, prefix)`` where ``tree`` is a
purely-functional search tree (canonical treap, ``pam.py``) keyed by the
*heads* — elements with ``h(e) mod b == 0`` — whose values are their
*tails* (vbyte-compressed chunks of the following non-head elements), and
``prefix`` is the chunk of elements before the first head.

Invariants (checked by ``check_invariants``):
  I1  every key in ``tree`` satisfies the head predicate;
  I2  chunks contain only non-head elements;
  I3  prefix elements < smallest head; tail(h) elements lie strictly
      between h and the next head;
  I4  chunks are sorted and duplicate-free.

Headness is a pure function of the element (hash), so an element is a head
in *any* C-tree containing it — the property that makes Union (Alg. 1)
work by splitting and joining whole chunks rather than re-chunking.

The tree is augmented with element counts so ``size`` is O(1).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import numpy as np

from .chunks import (
    Chunk,
    chunk_values,
    concat_chunks,
    split_chunk,
    union_chunks,
)
from .hash import is_head_np
from .pam import KEY, LEFT, RIGHT, VAL, Node, TreeModule

DEFAULT_B = 256
DEFAULT_SEED = 0x9E3779B9

# Head-tree module: aug = number of elements (head itself + its tail).
_MOD = TreeModule(
    aug_of=lambda k, tail: 1 + (tail.count if tail is not None else 0),
    combine=lambda a, b: a + b,
    zero=0,
)


class CTree(NamedTuple):
    """A compressed purely-functional ordered integer set."""

    tree: Node  # treap: head (int) -> tail (Chunk | None)
    prefix: Optional[Chunk]
    b: int = DEFAULT_B
    seed: int = DEFAULT_SEED

    # NamedTuple keeps this immutable: every operation returns a new CTree
    # sharing structure with its inputs — snapshots are O(1) (paper §1).


def empty(b: int = DEFAULT_B, seed: int = DEFAULT_SEED) -> CTree:
    return CTree(None, None, b, seed)


def is_empty(c: CTree) -> bool:
    return c.tree is None and c.prefix is None


def ctree_size(c: CTree) -> int:
    """O(1) via augmentation."""
    n = _MOD.aug(c.tree)
    if c.prefix is not None:
        n += c.prefix.count
    return n


# ---------------------------------------------------------------------------
# Build (paper §4.2 / Appendix 10.3)
# ---------------------------------------------------------------------------


def build(values, b: int = DEFAULT_B, seed: int = DEFAULT_SEED) -> CTree:
    """Build(S): sort, dedup, select heads by hash, chunk the rest."""
    values = np.unique(np.asarray(values, dtype=np.int64))
    if values.size == 0:
        return empty(b, seed)
    head_mask = is_head_np(values, b, np.uint32(seed))
    head_idx = np.flatnonzero(head_mask)
    if head_idx.size == 0:
        return CTree(None, Chunk.from_values(values), b, seed)
    prefix = Chunk.from_values(values[: head_idx[0]])
    bounds = np.append(head_idx, values.size)
    entries = []
    for j in range(head_idx.size):
        h = int(values[bounds[j]])
        tail = Chunk.from_values(values[bounds[j] + 1 : bounds[j + 1]])
        entries.append((h, tail))
    return CTree(_MOD.build_sorted(entries), prefix, b, seed)


def to_array(c: CTree) -> np.ndarray:
    """Decode the full ordered set (Map with identity)."""
    parts = []
    if c.prefix is not None:
        parts.append(c.prefix.values())
    for h, tail in _MOD.iter_entries(c.tree):
        parts.append(np.asarray([h], dtype=np.int64))
        if tail is not None:
            parts.append(tail.values())
    if not parts:
        return np.empty(0, dtype=np.int64)
    return np.concatenate(parts)


def map_elements(c: CTree, f) -> None:
    """Map(T, f): apply f to every element in order (paper §4)."""
    if c.prefix is not None:
        for v in c.prefix.values().tolist():
            f(v)
    for h, tail in _MOD.iter_entries(c.tree):
        f(h)
        if tail is not None:
            for v in tail.values().tolist():
                f(v)


# ---------------------------------------------------------------------------
# Find (paper §4.2)
# ---------------------------------------------------------------------------


def find(c: CTree, e: int) -> bool:
    """Membership: search heads for largest head <= e, then scan its tail."""
    if c.prefix is not None and c.prefix.first <= e <= c.prefix.last:
        v = c.prefix.values()
        i = int(np.searchsorted(v, e))
        return i < v.size and v[i] == e
    le = _MOD.find_le(c.tree, e)
    if le is None:
        return False
    h, tail = le
    if h == e:
        return True
    if tail is None or not (tail.first <= e <= tail.last):
        return False
    v = tail.values()
    i = int(np.searchsorted(v, e))
    return i < v.size and v[i] == e


# ---------------------------------------------------------------------------
# Split (paper Algorithm 3)
# ---------------------------------------------------------------------------


def _smallest_head(t: Node) -> Optional[int]:
    f = _MOD.first(t)
    return None if f is None else f[0]


def _split_tree(t: Node, k: int) -> Tuple[Node, bool, Node, Optional[Chunk]]:
    """Split a head-tree (no prefix) by k.

    Returns (left_tree, found, right_tree, right_prefix): ``right_prefix``
    is the chunk of non-heads between k and the right part's smallest head
    (k always lands either *on* a head — whose whole tail moves right — or
    *inside* one chunk, which splits locally; nothing ever dangles left).
    """
    if t is None:
        return None, False, None, None
    L, h, v, R = _MOD.expose(t)
    if k == h:
        # split exactly at a head; h's tail (all > h = k) moves right
        return L, True, R, v
    if k < h:
        lt, found, rt, rpre = _split_tree(L, k)
        return lt, found, _MOD.join(rt, h, v, R), rpre
    # k > h: does k fall inside h's tail?
    if v is not None and k <= v.last:
        v_l, found, v_r = split_chunk(v, k)
        return _MOD.join(L, h, v_l, None), found, R, v_r
    rt_l, found, rt_r, rpre = _split_tree(R, k)
    return _MOD.join(L, h, v, rt_l), found, rt_r, rpre


def split(c: CTree, k: int) -> Tuple[CTree, bool, CTree]:
    """Split(C, k) -> (elements < k, k in C, elements > k)  [Algorithm 3]."""
    b, seed = c.b, c.seed
    # Case: k interacts with the prefix
    if c.prefix is not None:
        if k <= c.prefix.last:
            p_l, found, p_r = split_chunk(c.prefix, k)
            return (
                CTree(None, p_l, b, seed),
                found,
                CTree(c.tree, p_r, b, seed),
            )
    lt, found, rt, rpre = _split_tree(c.tree, k)
    return CTree(lt, c.prefix, b, seed), found, CTree(rt, rpre, b, seed)


def _attach_trailing(c: CTree, chunk: Optional[Chunk]) -> CTree:
    """Append a chunk of non-heads (all larger than every element of c)."""
    if chunk is None:
        return c
    if c.tree is None:
        return CTree(None, concat_chunks(c.prefix, chunk), c.b, c.seed)
    t2, h, v = _MOD.split_last(c.tree)
    return CTree(_MOD.join(t2, h, concat_chunks(v, chunk), None), c.prefix, c.b, c.seed)


# ---------------------------------------------------------------------------
# Union (paper Algorithms 1 & 2)
# ---------------------------------------------------------------------------


def _split_chunk_at(chunk: Optional[Chunk], bound: Optional[int]) -> Tuple[Optional[Chunk], Optional[Chunk]]:
    """SplitChunk(chunk, bound): (< bound, > bound); bound=None => all left.
    ``bound`` is always a head, so it never occurs inside the chunk (I2)."""
    if chunk is None:
        return None, None
    if bound is None:
        return chunk, None
    l, found, r = split_chunk(chunk, bound)
    assert not found, "head found inside a chunk (invariant I2 violated)"
    return l, r


def union(c1: CTree, c2: CTree) -> CTree:
    """UNION (Algorithm 1)."""
    assert c1.b == c2.b and c1.seed == c2.seed
    b, seed = c1.b, c1.seed
    if c1.tree is None:
        return _union_bc(c1, c2)
    if c2.tree is None:
        return _union_bc(c2, c1)
    # expose C2's root
    L2, k2, v2, R2 = _MOD.expose(c2.tree)
    # split C1 by k2; B1 < k2 < B2=(BT2, BP2)
    B1, _found, B2 = split(c1, k2)
    BT2, BP2 = B2.tree, B2.prefix
    # elements of v2 (k2's tail) that belong past B2's first head
    v_l, v_r = _split_chunk_at(v2, _smallest_head(BT2))
    # elements of B2's prefix that belong past R2's first head
    p_l, p_r = _split_chunk_at(BP2, _smallest_head(R2))
    v2p = union_chunks(v_l, p_l)  # k2's new tail
    c_l = union(B1, CTree(L2, c2.prefix, b, seed))
    c_r = union(CTree(BT2, p_r, b, seed), CTree(R2, v_r, b, seed))
    assert c_r.prefix is None, "right union result must have empty prefix"
    return CTree(_MOD.join(c_l.tree, k2, v2p, c_r.tree), c_l.prefix, b, seed)


def _union_bc(c_bc: CTree, c: CTree) -> CTree:
    """UNIONBC (Algorithm 2): union a prefix-only C-tree into ``c``."""
    b, seed = c.b, c.seed
    P1 = c_bc.prefix
    if P1 is None:
        return c
    if c.tree is None:
        return CTree(None, union_chunks(P1, c.prefix), b, seed)
    # split P1 by the smallest head of c's tree
    p_l, p_r = _split_chunk_at(P1, _smallest_head(c.tree))
    new_prefix = union_chunks(p_l, c.prefix)
    tree = c.tree
    if p_r is not None:
        # each element of p_r joins the tail of its preceding head
        vals = p_r.values()
        # FindHead for each element, group ranges by unique head
        heads = np.empty(vals.size, dtype=np.int64)
        for i, e in enumerate(vals.tolist()):
            h, _ = _MOD.find_le(tree, e)
            heads[i] = h
        updates = []
        uniq, starts = np.unique(heads, return_index=True)
        bounds = np.append(starts, vals.size)
        for j, h in enumerate(uniq.tolist()):
            seg = vals[bounds[j] : bounds[j + 1]]
            old_tail = _MOD.find(tree, h)
            updates.append((h, union_chunks(old_tail, Chunk.from_values(seg))))
        tree = _MOD.multi_insert(tree, updates, combine_values=lambda old, new: new)
    return CTree(tree, new_prefix, b, seed)


# ---------------------------------------------------------------------------
# Difference / Intersection (paper §4.1: "conceptually very similar")
# ---------------------------------------------------------------------------


def _join2_ct(cl: CTree, cr: CTree) -> CTree:
    """Join two C-trees where all of cl < all of cr (no middle head).
    cr's prefix re-attaches to cl's largest head's tail."""
    b, seed = cl.b, cl.seed
    cl = _attach_trailing(cl, cr.prefix)
    return CTree(_MOD.join2(cl.tree, cr.tree), cl.prefix, b, seed)


def difference(c1: CTree, c2: CTree) -> CTree:
    """Elements of c1 not in c2 (drives MultiDelete)."""
    assert c1.b == c2.b and c1.seed == c2.seed
    b, seed = c1.b, c1.seed
    if is_empty(c1) or is_empty(c2):
        return c1
    if c2.tree is None:  # deletions are a single chunk
        return _delete_array(c1, c2.prefix.values())
    if c1.tree is None:  # data is a single chunk: filter by membership
        vals = c1.prefix.values()
        keep = np.fromiter((not find(c2, int(e)) for e in vals), bool, vals.size)
        return CTree(None, Chunk.from_values(vals[keep]), b, seed)
    L2, k2, v2, R2 = _MOD.expose(c2.tree)
    B1, _found, B2 = split(c1, k2)  # k2 dropped if present
    c_l = difference(B1, CTree(L2, c2.prefix, b, seed))
    c_r = difference(B2, CTree(R2, v2, b, seed))
    return _join2_ct(c_l, c_r)


def _delete_array(c: CTree, remove: np.ndarray) -> CTree:
    """Delete a sorted array of elements spanning c's range (small batch)."""
    b, seed = c.b, c.seed
    if remove.size == 0 or is_empty(c):
        return c
    out = c
    # split around each removed element's position: since |remove| is the
    # size of one chunk (O(b log n) w.h.p.), do it with split/join passes
    lo, found, rest = split(out, int(remove[0]))
    acc = lo
    for e in remove[1:].tolist():
        seg, found, rest = split(rest, int(e))
        acc = _join2_ct(acc, seg)
    return _join2_ct(acc, rest)


def intersect(c1: CTree, c2: CTree) -> CTree:
    """Elements present in both."""
    assert c1.b == c2.b and c1.seed == c2.seed
    b, seed = c1.b, c1.seed
    if is_empty(c1) or is_empty(c2):
        return empty(b, seed)
    if c2.tree is None:
        vals = c2.prefix.values()
        common = vals[np.fromiter((find(c1, int(e)) for e in vals), bool, vals.size)]
        return CTree(None, Chunk.from_values(common), b, seed)
    if c1.tree is None:
        return intersect(c2, c1)
    L2, k2, v2, R2 = _MOD.expose(c2.tree)
    B1, found, B2 = split(c1, k2)
    c_l = intersect(B1, CTree(L2, c2.prefix, b, seed))
    c_r = intersect(B2, CTree(R2, v2, b, seed))
    if found:
        # k2 is in both: it is a head of the result; the common non-heads
        # below the next surviving head (c_r.prefix) form its tail.
        return CTree(
            _MOD.join(c_l.tree, k2, c_r.prefix, c_r.tree), c_l.prefix, b, seed
        )
    return _join2_ct(c_l, c_r)


# ---------------------------------------------------------------------------
# Batch updates (paper §4.1)
# ---------------------------------------------------------------------------


def multi_insert(c: CTree, values) -> CTree:
    """MultiInsert = Union with a C-tree built over the batch."""
    return union(c, build(values, c.b, c.seed))


def multi_delete(c: CTree, values) -> CTree:
    """MultiDelete = Difference with a C-tree built over the batch."""
    return difference(c, build(values, c.b, c.seed))


def insert_one(c: CTree, e: int) -> CTree:
    return multi_insert(c, [e])


def delete_one(c: CTree, e: int) -> CTree:
    return multi_delete(c, [e])


# ---------------------------------------------------------------------------
# Memory accounting (paper §7.1 byte model) & invariants
# ---------------------------------------------------------------------------

# Paper sizes: uncompressed edge-tree node 32B; C-tree edge node 48B
# (key + tail pointer + children + size/aug) — §7.1.
UNCOMPRESSED_NODE_BYTES = 32
CTREE_NODE_BYTES = 48
CHUNK_HEADER_BYTES = 24  # count + cached first/last (Appendix 10.3)


def nbytes(c: CTree, compressed: bool = True) -> int:
    """Bytes used by this C-tree under the paper's memory model.

    compressed=True: vbyte chunk bytes; False: 8B per chunk element
    ("Aspen (No DE)" column of Table 2).
    """
    total = 0

    def chunk_bytes(ch: Optional[Chunk]) -> int:
        if ch is None:
            return 0
        payload = ch.nbytes if compressed else 8 * ch.count
        return CHUNK_HEADER_BYTES + payload

    total += chunk_bytes(c.prefix)

    def rec(t: Node) -> int:
        if t is None:
            return 0
        return (
            CTREE_NODE_BYTES
            + chunk_bytes(t[VAL])
            + rec(t[LEFT])
            + rec(t[RIGHT])
        )

    return total + rec(c.tree)


def uncompressed_tree_bytes(c: CTree) -> int:
    """Memory if the same set were a plain purely-functional tree."""
    return ctree_size(c) * UNCOMPRESSED_NODE_BYTES


def check_invariants(c: CTree) -> bool:
    """Validate I1-I4 plus the underlying treap invariants."""
    if not _MOD.check_invariants(c.tree):
        return False
    entries = list(_MOD.iter_entries(c.tree))
    heads = [h for h, _ in entries]
    # I1: keys are heads
    if not all(bool(is_head_np(np.int64(h), c.b, np.uint32(c.seed))) for h in heads):
        return False
    lo = -1
    if c.prefix is not None:
        pv = c.prefix.values()
        if (np.diff(pv) <= 0).any():
            return False
        if is_head_np(pv, c.b, np.uint32(c.seed)).any():  # I2
            return False
        if heads and pv[-1] >= heads[0]:  # I3
            return False
        if c.prefix.first != pv[0] or c.prefix.last != pv[-1]:
            return False
    for i, (h, tail) in enumerate(entries):
        nxt = heads[i + 1] if i + 1 < len(heads) else None
        if tail is not None:
            tv = tail.values()
            if (np.diff(tv) <= 0).any():
                return False
            if is_head_np(tv, c.b, np.uint32(c.seed)).any():  # I2
                return False
            if tv[0] <= h:
                return False
            if nxt is not None and tv[-1] >= nxt:  # I3
                return False
            if tail.first != tv[0] or tail.last != tv[-1]:
                return False
    return True
