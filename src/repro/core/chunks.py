"""Chunk compression for C-trees (paper §3.2, "Integer C-trees").

Two codecs:

1. ``vbyte_*`` — the paper's byte code: difference-encode the sorted chunk,
   then emit each delta as little-endian 7-bit groups with a continuation
   bit.  Sequential decode; used by the faithful host C-tree
   (core/ctree.py) and by the byte-accurate memory benchmarks (Table 2).

2. ``pack_deltas`` / ``unpack_deltas`` — the TPU adaptation: fixed-width
   deltas (uint8/uint16) with an escape side-table for overflowing deltas.
   Fixed width turns decode into a *vectorizable segmented cumsum* (the
   Pallas kernel in kernels/delta_decode.py) at a small ratio cost vs.
   byte codes, which the paper itself already traded toward decode speed
   (§3.2: "byte-codes ... fast to decode while achieving most of the
   memory savings").

Both codecs store the chunk's first element absolutely (the anchor) and the
first/last values cached at the chunk head so Split/Find can decide in O(1)
whether a key falls inside the chunk (paper §4.1 Split).
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np

# ---------------------------------------------------------------------------
# Paper-faithful byte code (vbyte over deltas)
# ---------------------------------------------------------------------------


def vbyte_encode_scalar(values: np.ndarray) -> bytes:
    """Reference scalar encoder (property-tested against the vector path)."""
    values = np.asarray(values, dtype=np.int64)
    if values.size == 0:
        return b""
    deltas = np.empty_like(values)
    deltas[0] = values[0]
    deltas[1:] = values[1:] - values[:-1]
    out = bytearray()
    for d in deltas.tolist():
        if d < 0:
            raise ValueError("chunk must be sorted/non-negative for vbyte")
        while True:
            byte = d & 0x7F
            d >>= 7
            if d:
                out.append(byte | 0x80)
            else:
                out.append(byte)
                break
    return bytes(out)


def vbyte_decode_scalar(buf: bytes) -> np.ndarray:
    """Reference scalar decoder."""
    vals = []
    acc = 0
    cur = 0
    shift = 0
    for byte in buf:
        cur |= (byte & 0x7F) << shift
        if byte & 0x80:
            shift += 7
        else:
            acc += cur
            vals.append(acc)
            cur = 0
            shift = 0
    return np.asarray(vals, dtype=np.int64)


def vbyte_encode(values: np.ndarray) -> bytes:
    """Difference + 7-bit varint encode a sorted int array (vectorized).

    <=10 masked vector passes (one per 7-bit group of a 64-bit delta)
    instead of a per-element Python loop; exact same byte stream as
    ``vbyte_encode_scalar``.
    """
    values = np.asarray(values, dtype=np.int64)
    n = values.size
    if n == 0:
        return b""
    deltas = np.empty(n, dtype=np.uint64)
    deltas[0] = values[0]
    if n > 1:
        d = values[1:] - values[:-1]
        if (d < 0).any() or values[0] < 0:
            raise ValueError("chunk must be sorted/non-negative for vbyte")
        deltas[1:] = d.astype(np.uint64)
    # bytes per delta: ceil(bit_length / 7) with min 1
    ngroups = np.ones(n, dtype=np.int64)
    thresh = np.uint64(1 << 7)
    tmp = deltas.copy()
    while True:
        more = tmp >= thresh
        if not more.any():
            break
        ngroups += more
        tmp = tmp >> np.uint64(7)
    offs = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(ngroups, out=offs[1:])
    out = np.zeros(offs[-1], dtype=np.uint8)
    max_g = int(ngroups.max())
    for g in range(max_g):
        sel = ngroups > g
        byte = ((deltas[sel] >> np.uint64(7 * g)) & np.uint64(0x7F)).astype(np.uint8)
        cont = (ngroups[sel] - 1 > g).astype(np.uint8) << 7
        out[offs[:-1][sel] + g] = byte | cont
    return out.tobytes()


def vbyte_decode(buf: bytes) -> np.ndarray:
    """Inverse of vbyte_encode (vectorized segmented shift-accumulate)."""
    if not buf:
        return np.empty(0, dtype=np.int64)
    raw = np.frombuffer(buf, dtype=np.uint8)
    is_last = (raw & 0x80) == 0
    starts = np.flatnonzero(np.concatenate(([True], is_last[:-1])))
    vidx = np.zeros(raw.size, dtype=np.int64)
    vidx[starts[1:]] = 1
    np.cumsum(vidx, out=vidx)
    pos = np.arange(raw.size, dtype=np.int64) - starts[vidx]
    contrib = (raw & 0x7F).astype(np.int64) << (7 * pos)
    deltas = np.add.reduceat(contrib, starts)
    return np.cumsum(deltas)


class Chunk(NamedTuple):
    """A compressed tail/prefix for the faithful C-tree.

    first/last are cached for O(1) range checks (paper Appendix 10.3:
    "store the first and last elements at the head of each chunk").
    """

    buf: bytes
    count: int
    first: int
    last: int

    @staticmethod
    def from_values(values: np.ndarray) -> "Chunk | None":
        values = np.asarray(values, dtype=np.int64)
        if values.size == 0:
            return None
        return Chunk(vbyte_encode(values), int(values.size),
                     int(values[0]), int(values[-1]))

    def values(self) -> np.ndarray:
        return vbyte_decode(self.buf)

    @property
    def nbytes(self) -> int:
        return len(self.buf)


EMPTY = None  # an empty chunk is represented as None throughout ctree.py


def chunk_values(c: "Chunk | None") -> np.ndarray:
    return c.values() if c is not None else np.empty(0, dtype=np.int64)


def split_chunk(c: "Chunk | None", k: int) -> tuple["Chunk | None", bool, "Chunk | None"]:
    """SplitChunk: (values < k, k present?, values > k)."""
    if c is None:
        return None, False, None
    # O(1) fast paths via cached first/last
    if k < c.first:
        return None, False, c
    if k > c.last:
        return c, False, None
    v = c.values()
    i = int(np.searchsorted(v, k, side="left"))
    found = i < v.size and v[i] == k
    left = Chunk.from_values(v[:i])
    right = Chunk.from_values(v[i + (1 if found else 0):])
    return left, bool(found), right


def union_chunks(a: "Chunk | None", b: "Chunk | None") -> "Chunk | None":
    if a is None:
        return b
    if b is None:
        return a
    merged = np.union1d(a.values(), b.values())
    return Chunk.from_values(merged)


def concat_chunks(a: "Chunk | None", b: "Chunk | None") -> "Chunk | None":
    """Concatenate chunks where all of ``a`` < all of ``b`` (no merge)."""
    if a is None:
        return b
    if b is None:
        return a
    assert a.last < b.first, "concat_chunks requires disjoint ordered chunks"
    return Chunk.from_values(np.concatenate([a.values(), b.values()]))


def diff_chunk(a: "Chunk | None", remove: np.ndarray) -> "Chunk | None":
    """Elements of ``a`` not present in sorted array ``remove``."""
    if a is None or remove.size == 0:
        return a
    v = a.values()
    keep = ~np.isin(v, remove, assume_unique=True)
    return Chunk.from_values(v[keep])


def intersect_chunk(a: "Chunk | None", other: np.ndarray) -> "Chunk | None":
    """Elements of ``a`` also present in sorted array ``other``."""
    if a is None or other.size == 0:
        return None
    v = a.values()
    return Chunk.from_values(v[np.isin(v, other, assume_unique=True)])


# ---------------------------------------------------------------------------
# TPU adaptation: fixed-width packed deltas with overflow escape
# ---------------------------------------------------------------------------


class PackedDeltas(NamedTuple):
    """Fixed-width delta pool over a flat sorted array partitioned into
    chunks at ``chunk_off`` boundaries.  Chunk i's first element is stored
    absolutely in ``anchors[i]``; subsequent deltas are ``width``-bit with
    the all-ones pattern escaping to ``overflow``.
    """

    deltas: np.ndarray      # uint8/uint16 [n] (delta of element vs predecessor; anchor pos holds 0)
    anchors: np.ndarray     # int64 [n_chunks] absolute first element per chunk
    chunk_off: np.ndarray   # int64 [n_chunks + 1] offsets into deltas
    overflow: np.ndarray    # int64 [n_overflow] escaped deltas in order
    dtype: str              # "uint8" | "uint16"

    @property
    def nbytes(self) -> int:
        return (self.deltas.nbytes + self.anchors.nbytes
                + self.chunk_off.nbytes + self.overflow.nbytes)


def pack_deltas(data: np.ndarray, chunk_off: np.ndarray, width: str = "uint16") -> PackedDeltas:
    data = np.asarray(data, dtype=np.int64)
    chunk_off = np.asarray(chunk_off, dtype=np.int64)
    n = data.size
    esc = np.iinfo(np.dtype(width)).max
    deltas = np.zeros(n, dtype=np.int64)
    if n:
        deltas[1:] = data[1:] - data[:-1]
    anchors = data[chunk_off[:-1]] if chunk_off.size > 1 else np.empty(0, np.int64)
    if chunk_off.size > 1:
        deltas[chunk_off[:-1]] = 0  # anchor positions carry no delta
    ovf_mask = deltas >= esc
    overflow = deltas[ovf_mask]
    packed = np.where(ovf_mask, esc, deltas).astype(np.dtype(width))
    return PackedDeltas(packed, anchors, chunk_off, overflow, width)


def unpack_deltas(p: PackedDeltas) -> np.ndarray:
    """Reference (numpy) decode: segmented cumsum of deltas from anchors.
    The jit/Pallas equivalents live in kernels/delta_decode.py."""
    esc = np.iinfo(np.dtype(p.dtype)).max
    d = p.deltas.astype(np.int64)
    ovf_mask = d == esc
    d[ovf_mask] = p.overflow
    if p.chunk_off.size > 1:
        d[p.chunk_off[:-1]] = p.anchors
    # segmented cumsum: subtract the running total at each chunk start
    out = np.cumsum(d)
    if p.chunk_off.size > 1:
        starts = p.chunk_off[:-1]
        base = out[starts] - p.anchors
        out -= np.repeat(base, np.diff(p.chunk_off))
    return out
