"""Device-resident chunked delta encoding (the compressed pool lane).

The paper stores massive graphs at a few bytes per edge by chunking each
C-tree and difference-encoding within chunks (§3.2).  ``chunks.py`` holds
the host-side codecs (paper-faithful vbyte, and the fixed-width
``pack_deltas`` reference); this module is the DEVICE layout those
reference: a sorted-ish int32 stream cut into fixed ``CHUNK``-slot rows,
each row stored as

  ``(anchor int32, deltas int8|int16[CHUNK], escape corrections)``

where ``deltas[:, 0] == 0`` (the anchor position) and decode is the
batched row cumsum the seed Pallas kernel (``kernels/delta_decode.py``)
implements — zero serial dependence between chunks.

Fixed chunk geometry (vs. the paper's hash-canonical boundaries) is what
makes the layout *streaming-maintainable* under jit: every shape is
static, so the same compiled encode/decode serves a whole update stream,
and ``CHUNK`` divides the segment-sum kernel's edge block so decode can
fuse into the reduce as an in-kernel prologue (no chunk ever straddles a
kernel tile).

Escape lane
-----------
A delta that overflows the fixed-width lane (|delta| > 127 for int8,
> 32767 for int16) is stored as 0 in the lane and carried in a per-chunk
escape table of ``k`` (static) slots: ``ovf_pos[r, j]`` is the column of
the j-th escaped delta in chunk ``r`` (ascending; ``CHUNK`` marks an
unused slot) and ``ovf_add[r, j]`` the full int32 delta.  Because each
correction applies to every column >= its position, decode stays a pure
cumsum plus ``k`` masked adds — the scan-carry never has to branch.  A
chunk with more than ``k`` escapes sets the ``spill`` flag: the stream no
longer round-trips and callers must fall back to the raw layout (host
builders check the flag once; see ``flat_graph.compress_host``).

Adaptive per-chunk widths (DESIGN.md §12)
-----------------------------------------
A fixed lane width wastes a byte per slot on every chunk whose deltas fit
int8 — ``flat_graph.chunk_stats`` measures exactly that gap
(``bytes_ideal``).  The adaptive layout closes it: the lane stays ONE
int8 plane (field ``deltas``), and each chunk carries a width tag
(``wide`` bool[R]).  A narrow chunk stores its signed delta in the lane
directly; a wide chunk stores the delta's LOW byte (two's-complement bit
pattern) in the lane and its HIGH byte in a *compacted* second plane
``hi`` (int8[H, CHUNK]) holding only the wide chunks' rows, in chunk
order.  The hi-row index is never stored — it is
``cumsum(wide) - 1``, recomputed in-trace — so decode stays branch-free:

  ``delta = wide ? hi * 256 + (lane & 0xFF) : lane``

(``stored >> 8`` / ``stored & 0xFF`` is an exact int16 split: the
arithmetic shift keeps ``hi`` in int8 range for any |delta| <= 32767).
The escape lane is unchanged — int8-range escapes are free in a narrow
chunk (the k slots are statically allocated), so a chunk only goes wide
when it has MORE than ``k`` over-int8 deltas; per-slot escapes then use
the int16 limit.  ``H`` (the hi-plane capacity) is static; more wide
chunks than ``H`` fold into the same ``spill`` flag as escape overflow,
and streaming callers rebuild from the source (``AspenStream`` mirrors
carry headroom so this is rare).  Bytes/chunk: narrow 197 vs wide 325 vs
fixed-int16 324 — adaptive never loses unless every chunk is wide.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

CHUNK = 128  # slots per chunk; divides segment_reduce.EDGE_BLOCK (512)
OVF_SLOTS = 8  # default static escape-lane capacity per chunk

_WIDTH_DTYPE = {1: jnp.int8, 2: jnp.int16}
_WIDTH_LIMIT = {1: 127, 2: 32767}


class ChunkedStream(NamedTuple):
    """Delta-encoded int32 stream in fixed ``CHUNK``-slot rows; a pytree.

    anchors : int32[R]        absolute value at each chunk start
    deltas  : int8|int16[R, CHUNK]  col 0 == 0; escaped deltas hold 0
    ovf_pos : int32[R, K]     column of each escaped delta (pad CHUNK)
    ovf_add : int32[R, K]     the escaped delta's full value
    spill   : bool[]          some chunk had > K escapes (decode unsound)
    hi      : int8[H, CHUNK]  adaptive only: compacted high-byte plane
                              (None on fixed-width streams)
    wide    : bool[R]         adaptive only: per-chunk width tag

    The encoded length is ``R * CHUNK``; streams shorter than that are
    tail-padded by repeating the last element (delta 0), so decode of the
    padded region is benign and callers slice to their own length.
    """

    anchors: jax.Array
    deltas: jax.Array
    ovf_pos: jax.Array
    ovf_add: jax.Array
    spill: jax.Array
    hi: Optional[jax.Array] = None
    wide: Optional[jax.Array] = None

    @property
    def length(self) -> int:
        return self.deltas.shape[-2] * self.deltas.shape[-1]

    @property
    def width(self) -> int:
        return jnp.dtype(self.deltas.dtype).itemsize

    @property
    def k(self) -> int:
        return self.ovf_pos.shape[-1]

    @property
    def adaptive(self) -> bool:
        return self.hi is not None

    @property
    def hi_cap(self) -> int:
        """Static hi-plane capacity in chunks (0 on fixed-width streams)."""
        return 0 if self.hi is None else self.hi.shape[-2]


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def _encode_impl(values: jax.Array, width: int, k: int) -> ChunkedStream:
    if width not in _WIDTH_DTYPE:
        raise ValueError(f"width must be 1 or 2 bytes, got {width}")
    rows, deltas = _chunk_deltas(values)
    lim = _WIDTH_LIMIT[width]
    esc = (deltas < -lim) | (deltas > lim)
    stored = jnp.where(esc, 0, deltas).astype(_WIDTH_DTYPE[width])
    R = rows.shape[0]
    cols = jax.lax.broadcasted_iota(jnp.int32, (R, CHUNK), 1)
    pos_all = jnp.where(esc, cols, jnp.int32(CHUNK))
    order = jnp.argsort(pos_all, axis=1)[:, :k]  # escapes first, ascending
    ovf_pos = jnp.take_along_axis(pos_all, order, axis=1)
    ovf_add = jnp.take_along_axis(jnp.where(esc, deltas, 0), order, axis=1)
    spill = (esc.sum(axis=1) > k).any()
    return ChunkedStream(
        anchors=rows[:, 0].astype(jnp.int32),
        deltas=stored,
        ovf_pos=ovf_pos.astype(jnp.int32),
        ovf_add=ovf_add.astype(jnp.int32),
        spill=spill,
    )


encode_stream = functools.partial(jax.jit, static_argnames=("width", "k"))(
    lambda values, width=2, k=OVF_SLOTS: _encode_impl(values, width, k)
)
encode_stream.__doc__ = (
    "jit encode: int32[L] -> ChunkedStream (static width in bytes, static"
    " escape capacity k).  See the module docstring for the layout."
)


def _chunk_deltas(values: jax.Array):
    """Shared chunking prologue: edge-padded (R, CHUNK) rows + their
    within-chunk deltas (col 0 == 0)."""
    L = values.shape[0]
    if L == 0:
        values = jnp.zeros((1,), jnp.int32)
        L = 1
    Lp = _round_up(L, CHUNK)
    v = jnp.pad(values.astype(jnp.int32), (0, Lp - L), mode="edge")
    rows = v.reshape(-1, CHUNK)
    prev = jnp.concatenate([rows[:, :1], rows[:, :-1]], axis=1)
    return rows, rows - prev


def _encode_adaptive_impl(
    values: jax.Array, hi_cap: int, k: int
) -> ChunkedStream:
    """Adaptive-width encode (module docstring): one int8 lane + a
    compacted hi-byte plane of STATIC capacity ``hi_cap`` chunks.  A
    chunk goes wide iff more than ``k`` of its deltas overflow int8
    (narrow escapes are free — the k slots exist either way); running
    out of hi-plane rows folds into ``spill`` exactly like escape
    overflow."""
    rows, deltas = _chunk_deltas(values)
    R = rows.shape[0]
    abs_d = jnp.abs(deltas)
    wide = (abs_d > _WIDTH_LIMIT[1]).sum(axis=1) > k  # bool[R]
    lim = jnp.where(wide[:, None], _WIDTH_LIMIT[2], _WIDTH_LIMIT[1])
    esc = abs_d > lim
    stored = jnp.where(esc, 0, deltas)  # int32, |.| <= per-chunk limit
    # lane = signed low byte (== the full delta on narrow chunks)
    lane = (((stored & 0xFF) ^ 0x80) - 0x80).astype(jnp.int8)
    cols = jax.lax.broadcasted_iota(jnp.int32, (R, CHUNK), 1)
    pos_all = jnp.where(esc, cols, jnp.int32(CHUNK))
    order = jnp.argsort(pos_all, axis=1)[:, :k]
    ovf_pos = jnp.take_along_axis(pos_all, order, axis=1)
    ovf_add = jnp.take_along_axis(jnp.where(esc, deltas, 0), order, axis=1)
    wide_i = wide.astype(jnp.int32)
    hi_idx = jnp.cumsum(wide_i) - 1  # compacted row per wide chunk
    target = jnp.where(wide, hi_idx, hi_cap)
    hi = (
        jnp.zeros((hi_cap, CHUNK), jnp.int8)
        .at[target]
        .set(jnp.where(wide[:, None], stored >> 8, 0).astype(jnp.int8),
             mode="drop")
    )
    spill = (esc.sum(axis=1) > k).any() | (wide_i.sum() > hi_cap)
    return ChunkedStream(
        anchors=rows[:, 0].astype(jnp.int32),
        deltas=lane,
        ovf_pos=ovf_pos.astype(jnp.int32),
        ovf_add=ovf_add.astype(jnp.int32),
        spill=spill,
        hi=hi,
        wide=wide,
    )


encode_stream_adaptive = functools.partial(
    jax.jit, static_argnames=("hi_cap", "k")
)(lambda values, hi_cap, k=OVF_SLOTS: _encode_adaptive_impl(values, hi_cap, k))
encode_stream_adaptive.__doc__ = (
    "jit adaptive encode: int32[L] -> ChunkedStream with per-chunk width"
    " tags (static hi-plane capacity in chunks, static escape capacity k)."
)


def adaptive_deltas(c: ChunkedStream) -> jax.Array:
    """Reconstruct the per-slot int32 deltas of an adaptive stream's lane
    (escapes still 0 — callers add the ovf corrections).  The branch-free
    width select: wide ? hi * 256 + (lane & 0xFF) : lane, with the
    compacted hi row recovered in-trace as ``cumsum(wide) - 1``.
    ndim-aware like ``decode_rows`` (leaves may be (S, ...)-batched)."""
    lane = c.deltas.astype(jnp.int32)
    H = c.hi.shape[-2]
    if H == 0:
        # no wide chunk can exist without spilling; lane is exact
        return lane
    idx = jnp.clip(
        jnp.cumsum(c.wide.astype(jnp.int32), axis=-1) - 1, 0, H - 1
    )
    hi_g = jnp.take_along_axis(c.hi.astype(jnp.int32), idx[..., None], axis=-2)
    return jnp.where(c.wide[..., None], hi_g * 256 + (lane & 0xFF), lane)


def decode_rows(c: ChunkedStream) -> jax.Array:
    """Pure-jnp decode to (R, CHUNK) int32 rows: anchor + row cumsum plus
    the escape-lane step corrections.  Traced inline by every consumer so
    XLA fuses the decode with whatever reads it — the non-Pallas half of
    the fused-decode contract (the Pallas half lives in
    ``kernels/delta_decode.py`` / ``kernels/segment_reduce.py``)."""
    d = adaptive_deltas(c) if c.hi is not None else c.deltas.astype(jnp.int32)
    base = c.anchors[..., None] + jnp.cumsum(d, axis=-1)
    cols = jax.lax.broadcasted_iota(jnp.int32, c.deltas.shape, c.deltas.ndim - 1)
    corr = jnp.sum(
        jnp.where(cols[..., None] >= c.ovf_pos[..., None, :], c.ovf_add[..., None, :], 0),
        axis=-1,
    )
    return base + corr


def decode_stream(c: ChunkedStream, length: int | None = None) -> jax.Array:
    """Decode to a flat int32 array (first ``length`` slots; full padded
    stream when None)."""
    flat = decode_rows(c).reshape(*c.deltas.shape[:-2], -1)
    if length is None:
        return flat
    return flat[..., :length]


def stream_nbytes(c: ChunkedStream) -> int:
    """Device-resident bytes of the stream (host accounting helper)."""
    arrays = [c.anchors, c.deltas, c.ovf_pos, c.ovf_add]
    if c.hi is not None:
        arrays += [c.hi, c.wide]
    return sum(
        int(np.prod(a.shape)) * jnp.dtype(a.dtype).itemsize for a in arrays
    )


def pytree_nbytes(tree) -> int:
    """Total bytes of every array leaf of a pytree (host accounting for
    the BYTES bench / ``TraversalEngine.resident_nbytes``)."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        if hasattr(leaf, "shape") and hasattr(leaf, "dtype"):
            total += int(np.prod(leaf.shape)) * jnp.dtype(leaf.dtype).itemsize
    return total
